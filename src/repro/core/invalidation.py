"""Cache maintenance under gRW-Txs (§3.2 + Appendix A), vectorized.

``invalidate_write_around`` implements Algorithms 1–9 over a *batch* of
mutations × all registered templates, entirely as tensor ops:

- Algorithm 6 (DeleteKeysForRoot / FDB clearRange)  -> ``sweep_root``
- Algorithm 7 (DeleteKeysForLeaf, reverse traversal) -> ``_delete_keys_for_leaf``
- Algorithm 8 (HandleEdgeChange)                     -> ``_handle_edge_change``
- Algorithms 1–4 are the per-change-type drivers below.

``write_through_update`` is the §3 write-through policy (designed but not
implemented in the paper — we implement it as a beyond-paper feature):
instead of deleting impacted entries it appends/removes single vertex ids
in place, falling back to deletion for multi-chunk or full entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cache import CacheSpec, CacheState, cache_delete, sweep_root, _probe
from repro.core.keys import PARAM_LEN
from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    PredSpec,
    TemplateTable,
    evaluate_pred,
    extract_wildcards,
)
from repro.graphstore.store import GraphStore, gather_in, gather_out
from repro.graphstore.mutations import AppliedMutations
from repro.utils import NULL_ID, PROP_MISSING, compact_masked, take_along0


def _pred_row(stacked: PredSpec, t: int) -> PredSpec:
    return PredSpec(*(getattr(stacked, f)[t] for f in PredSpec._fields))


def _has_all_wildcards(pred: PredSpec, props):
    """Algorithm 7 line 2 / Algorithm 8 line 2: element must carry every
    wildcard property of the predicate."""
    ok = jnp.ones(props.shape[:-1], bool)
    for c in range(MAX_CONDS):
        pid = pred.prop_ids[c]
        need = (pid >= 0) & pred.wild[c]
        pv = jnp.take(props, jnp.clip(pid, 0, props.shape[-1] - 1), axis=-1)
        ok &= ~need | (pv != PROP_MISSING)
    return ok


def _prop_in_pred(pred: PredSpec, pid):
    """'P appears in P^x' test, vectorized over a batch of pids."""
    hit = jnp.zeros(jnp.shape(pid), bool)
    for c in range(MAX_CONDS):
        hit |= (pred.prop_ids[c] >= 0) & (pred.prop_ids[c] == pid)
    return hit


def _handle_edge_change(
    espec,
    cache: CacheState,
    ttable: TemplateTable,
    t: int,
    store_ep: GraphStore,
    elabel,
    eprops,
    src,
    dst,
    active,
    value_delta=None,
):
    """Algorithm 8 over a batch of edges. ``store_ep`` supplies endpoint
    labels/properties (pre- or post-state per the caller's change type).

    ``value_delta``: None -> write-around (delete keys); +1 -> write-through
    append leaf; -1 -> write-through remove leaf.
    """
    cspec = espec.cache
    pe = _pred_row(ttable.pe, t)
    pr = _pred_row(ttable.pr, t)
    pl = _pred_row(ttable.pl, t)
    direction = ttable.direction[t]
    elab_t = ttable.edge_label[t]

    e_ok = active & _has_all_wildcards(pe, eprops) & evaluate_pred(pe, elabel, eprops)
    e_ok &= (elab_t < 0) | (elabel == elab_t)
    we = extract_wildcards(pe, eprops)  # [K, MAXC]

    use_rl = (direction == DIR_OUT) | (direction == DIR_BOTH)  # R=src, L=dst
    use_lr = (direction == DIR_IN) | (direction == DIR_BOTH)  # R=dst, L=src
    for R, L, use in ((src, dst, use_rl), (dst, src, use_lr)):
        rlab = take_along0(store_ep.vlabel, R)
        rprops = take_along0(store_ep.vprops, R)
        llab = take_along0(store_ep.vlabel, L)
        lprops = take_along0(store_ep.vprops, L)
        ok = (
            e_ok
            & use
            & _has_all_wildcards(pl, lprops)
            & evaluate_pred(pr, rlab, rprops)
            & evaluate_pred(pl, llab, lprops)
        )
        wl = extract_wildcards(pl, lprops)
        params = jnp.concatenate([we, wl], axis=-1)
        if value_delta is None:
            cache = cache_delete(cspec, cache, jnp.full(R.shape, t), R, params, ok)
        else:
            cache = _value_update(cspec, cache, t, R, params, L, ok, value_delta)
    return cache


def _delete_keys_for_leaf(
    espec,
    cache: CacheState,
    ttable: TemplateTable,
    t: int,
    store_trav: GraphStore,
    leaf_vid,
    leaf_label,
    leaf_props,
    active,
    value_delta=None,
):
    """Algorithm 7 over a batch of leaves: reverse-traverse to each possible
    root and delete (or write-through update) the corresponding keys."""
    cspec = espec.cache
    pe = _pred_row(ttable.pe, t)
    pr = _pred_row(ttable.pr, t)
    pl = _pred_row(ttable.pl, t)
    direction = ttable.direction[t]
    elab_t = ttable.edge_label[t]

    act = active & _has_all_wildcards(pl, leaf_props)
    act &= evaluate_pred(pl, leaf_label, leaf_props)
    wl = extract_wildcards(pl, leaf_props)  # [K, MAXC]

    # reverse query: template OUT -> roots via the leaf's incoming edges;
    # template IN -> via outgoing; BOTH -> both sides.
    use_in = (direction == DIR_OUT) | (direction == DIR_BOTH)
    use_out = (direction == DIR_IN) | (direction == DIR_BOTH)
    sides = (
        (gather_in(espec.store, store_trav, leaf_vid, espec.max_deg), use_in),
        (gather_out(espec.store, store_trav, leaf_vid, espec.max_deg), use_out),
    )
    for (eids, roots, emask, _trunc), use in sides:
        elab = take_along0(store_trav.elabel, eids)
        ep = take_along0(store_trav.eprops, eids)
        ok = emask & act[:, None] & use
        ok &= (elab_t < 0) | (elab == elab_t)
        ok &= _has_all_wildcards(pe, ep) & evaluate_pred(pe, elab, ep)
        we = extract_wildcards(pe, ep)  # [K, W, MAXC]
        rlab = take_along0(store_trav.vlabel, roots)
        rprops = take_along0(store_trav.vprops, roots)
        ok &= evaluate_pred(pr, rlab, rprops)
        params = jnp.concatenate(
            [we, jnp.broadcast_to(wl[:, None, :], we.shape)], axis=-1
        )
        K, W = roots.shape
        flat = lambda x: x.reshape((K * W,) + x.shape[2:])
        if value_delta is None:
            cache = cache_delete(
                cspec, cache, jnp.full((K * W,), t), flat(roots), flat(params), flat(ok)
            )
        else:
            leaf_b = jnp.broadcast_to(leaf_vid[:, None], (K, W))
            cache = _value_update(
                cspec, cache, t, flat(roots), flat(params), flat(leaf_b), flat(ok), value_delta
            )
    return cache


def _value_update(cspec: CacheSpec, cache: CacheState, t, root, params, vid, mask, delta):
    """Write-through in-place value edit: append (delta=+1) or remove
    (delta=-1) ``vid`` from the entry's leaf list. Single-chunk entries only;
    multi-chunk or full entries fall back to write-around deletion. Walks the
    batch sequentially (write path)."""
    L = cspec.max_leaves
    K = root.shape[0]
    tpl = jnp.full((K,), t, jnp.int32)

    def body(i, cache):
        found, slot, _, _ = _probe(cspec, cache, tpl[i], root[i], params[i], 0)
        s = jnp.clip(slot, 0)
        tlen = cache.total_len[s]
        single = tlen <= L
        do = mask[i] & found
        row = cache.vals[s]
        present = jnp.any((row == vid[i]) & (jnp.arange(L) < tlen))
        if delta > 0:
            new_row = row.at[jnp.clip(tlen, 0, L - 1)].set(vid[i])
            new_len = tlen + 1
            write = do & single & ~present & (tlen < L)
            # full entry (or multi-chunk chain): fall back to write-around
            kill = do & (~single | ((tlen >= L) & ~present))
        else:
            keep = (row != vid[i]) & (jnp.arange(L) < tlen)
            new_row, _ = compact_masked(row, keep, L)
            new_len = jnp.sum(keep.astype(jnp.int32))
            write = do & single & present
            kill = do & ~single
        tgt = jnp.where(write, s, cspec.capacity)
        cache = cache._replace(
            vals=cache.vals.at[tgt].set(jnp.where(write, new_row, row), mode="drop"),
            total_len=cache.total_len.at[tgt].set(
                jnp.where(write, new_len, tlen), mode="drop"
            ),
        )
        kt = jnp.where(kill, s, cspec.capacity)
        cache = cache._replace(
            valid=cache.valid.at[kt].set(False, mode="drop"),
            n_delete=cache.n_delete + jnp.where(kill, 1, 0),
        )
        return cache

    return jax.lax.fori_loop(0, K, body, cache)


def _sec(mask_len, ids):
    return jnp.arange(ids.shape[0]) < mask_len


def _run_policy(
    espec, store_pre, store_post, cache, ttable, applied: AppliedMutations, *, through: bool
):
    b = applied.batch
    T = int(ttable.direction.shape[0])
    nv = espec.store.n_vprops

    ne_m = _sec(b.ne_n, b.ne_src)
    de_m = _sec(b.de_n, b.de_eid)
    se_m = _sec(b.se_n, b.se_eid)
    sv_m = _sec(b.sv_n, b.sv_vid)
    dv_m = _sec(b.dv_n, b.dv_vid)

    # edge-prop change = delete old edge + add new edge (Example 5)
    pid_col = jnp.clip(b.se_pid, 0, espec.store.n_eprops - 1)
    se_old_props = applied.se_props.at[
        jnp.arange(b.se_eid.shape[0]), pid_col
    ].set(applied.se_old)

    # vertex-prop pre/post rows
    sv_post = take_along0(store_post.vprops, b.sv_vid)
    vpid_col = jnp.clip(b.sv_pid, 0, nv - 1)
    sv_pre = sv_post.at[jnp.arange(b.sv_vid.shape[0]), vpid_col].set(applied.sv_old)
    sv_lab = take_along0(store_post.vlabel, b.sv_vid)

    dv_lab = take_along0(store_pre.vlabel, b.dv_vid)
    dv_props = take_along0(store_pre.vprops, b.dv_vid)

    add_d = +1 if through else None
    del_d = -1 if through else None

    for t in range(T):
        wen = ttable.write_enabled[t]
        pr = _pred_row(ttable.pr, t)
        pl = _pred_row(ttable.pl, t)

        # --- Algorithm 3: add edges (post state) / delete edges (pre state)
        cache = _handle_edge_change(
            espec, cache, ttable, t, store_post,
            b.ne_label, b.ne_props, b.ne_src, b.ne_dst, ne_m & wen,
            value_delta=add_d,
        )
        cache = _handle_edge_change(
            espec, cache, ttable, t, store_pre,
            applied.de_label, applied.de_props, applied.de_src, applied.de_dst,
            de_m & wen, value_delta=del_d,
        )

        # --- Algorithm 4: edge property change (only templates whose P^e
        # references the property)
        in_pe = _prop_in_pred(_pred_row(ttable.pe, t), b.se_pid)
        cache = _handle_edge_change(
            espec, cache, ttable, t, store_pre,
            applied.se_label, se_old_props, applied.se_src, applied.se_dst,
            se_m & wen & in_pe, value_delta=del_d,
        )
        cache = _handle_edge_change(
            espec, cache, ttable, t, store_post,
            applied.se_label, applied.se_props, applied.se_src, applied.se_dst,
            se_m & wen & in_pe, value_delta=add_d,
        )

        # --- Algorithm 2: vertex property change
        in_pr = _prop_in_pred(pr, b.sv_pid)
        r_hit = evaluate_pred(pr, sv_lab, sv_pre) | evaluate_pred(pr, sv_lab, sv_post)
        # root-side changes clear the whole (template, root) range — both
        # policies delete (write-through has no cheaper option, §3.2)
        cache = sweep_root(
            espec.cache, cache, jnp.full(b.sv_vid.shape, t), b.sv_vid,
            sv_m & wen & in_pr & r_hit,
        )
        in_pl = _prop_in_pred(pl, b.sv_pid)
        cache = _delete_keys_for_leaf(
            espec, cache, ttable, t, store_post, b.sv_vid, sv_lab, sv_pre,
            sv_m & wen & in_pl, value_delta=del_d,
        )
        cache = _delete_keys_for_leaf(
            espec, cache, ttable, t, store_post, b.sv_vid, sv_lab, sv_post,
            sv_m & wen & in_pl, value_delta=add_d,
        )

        # --- Algorithm 1: delete vertex (pre state)
        r_ok = evaluate_pred(pr, dv_lab, dv_props)
        cache = sweep_root(
            espec.cache, cache, jnp.full(b.dv_vid.shape, t), b.dv_vid,
            dv_m & wen & r_ok,
        )
        cache = _delete_keys_for_leaf(
            espec, cache, ttable, t, store_pre, b.dv_vid, dv_lab, dv_props,
            dv_m & wen, value_delta=del_d,
        )
    return cache


def invalidate_write_around(espec, store_pre, store_post, cache, ttable, applied):
    """Write-around policy (§4): delete every impacted cache entry, in the
    same commit as the graph writes."""
    return _run_policy(
        espec, store_pre, store_post, cache, ttable, applied, through=False
    )


def write_through_update(espec, store_pre, store_post, cache, ttable, applied):
    """Write-through policy (§3.2, lazy variant): update impacted entries in
    place where possible, delete where not."""
    return _run_policy(
        espec, store_pre, store_post, cache, ttable, applied, through=True
    )
