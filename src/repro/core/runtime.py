"""The shared transaction-runtime substrate.

Both entry points of the system compile against this module: the
single-host ``GraphEngine`` (core/engine.py) and the sharded serve tier
(distributed/graph_serve.py). It owns everything that used to be duplicated
between them:

- ``onehop_exec``          — one one-hop sub-query instance per root (the
                             cache-miss path; Definition 2.1 semantics).
- ``make_hop_kernel``      — one hop of the fused gR-Tx pipeline: lean cache
                             probe + ``lax.cond``-gated masked miss
                             execution over a flat root frontier. The
                             sharded runtime runs this same kernel at the
                             *owner* shard after routing; the single-host
                             engine runs it in place.
- ``make_fused_plan_fn``   — the whole-plan fused pipeline (PR 2): all hops,
                             on-device frontier merges, final clause, device
                             metrics. The single-host engine jits this
                             directly; it is byte-identical to running the
                             hop kernels inside ``shard_map`` on a 1-shard
                             mesh.
- bucketing / padding      — ``BUCKETS`` / ``bucket_for`` / ``pad_roots``
                             (previously copied between ``GraphEngine`` and
                             ``CachePopulator``) and the MoE-style routing
                             primitives ``route_plan`` / ``route_scatter`` /
                             ``bucketize`` (previously private to
                             ``graph_serve``). ``bucketize`` surfaces an
                             *overflow count* — valid items dropped because
                             a peer bucket filled up — so serving tiers can
                             alert on silent truncation.
- ``get_grw_step``         — the jitted gRW-Tx commit (apply mutations +
                             cache maintenance in one functional state
                             transition), cached by ``(espec, policy)`` so
                             repeated ``run_grw_tx`` calls never re-trace.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import cache_lookup_lean
from repro.core.keys import PARAM_LEN
from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    evaluate_pred,
)
from repro.graphstore.store import GlobalStoreView
from repro.utils import (
    NULL_ID,
    compact_masked,
    dedup_masked,
    segmented_dedup_merge,
    take_along0,
)

# final-clause codes of a QueryPlan
FINAL_IDS, FINAL_COUNT, FINAL_VALUES = 0, 1, 2

# ------------------------------------------------------- packed wire format
# One hop exchange each direction moves ONE contiguous int32 buffer (one
# all_to_all), instead of the former separate root / value / count phases.
#
# Query frame (querier -> owner), int32 lanes per routed row:
#     [0]              root vertex id (>= 0 for delivered rows)
#     [1]              flags — bit 0 (WIRE_FLAG_VALID) marks a live row;
#                      bucket padding is zero-filled, so its flags are 0
#     [2 : 2+PARAM_LEN] the hop's bound predicate params (wildcard values)
#
# Result frame (owner -> querier), int32 lanes per row:
#     [0 : RW]         left-packed leaf ids (cache hit or miss exec)
#     [RW]             count lane, doubling as the hit/miss/deferred flag:
#                      >= 0 is the leaf count (hit or executed miss),
#                      -1 marks a row deferred at a down owner
WIRE_FLAG_VALID = 1
WIRE_QUERY_LANES = 2 + PARAM_LEN


def pack_query_frame(roots, flags, params):
    """Pack routed query rows into the contiguous wire layout above.

    ``roots`` int32 [M], ``flags`` int32 [M], ``params`` int32
    [M, PARAM_LEN] -> int32 [M, WIRE_QUERY_LANES].
    """
    return jnp.concatenate(
        [roots[:, None], flags[:, None], params], axis=1
    ).astype(jnp.int32)


def unpack_query_frame(frame):
    """Inverse of ``pack_query_frame``: (roots, flags, params)."""
    return frame[..., 0], frame[..., 1], frame[..., 2:]


def pack_result_frame(vals, cnt):
    """Pack per-row results + count/flag lane: [M, RW] + [M] -> [M, RW+1]."""
    return jnp.concatenate(
        [vals, cnt[..., None].astype(vals.dtype)], axis=-1
    )


def unpack_result_frame(frame):
    """Inverse of ``pack_result_frame``: (vals [M, RW], cnt [M])."""
    return frame[..., :-1], frame[..., -1]

# batch buckets: gR-Tx batches are padded to the next bucket so the jit
# cache stays small. ``CachePopulator`` uses the prefix ``BUCKETS[:4]``.
BUCKETS = (8, 32, 128, 512, 2048, 8192)


def bucket_for(k: int, buckets=BUCKETS, clamp: bool = False) -> int:
    """Smallest bucket >= k; next power of two (or, clamped, the largest
    bucket — the caller then chunks) beyond the table."""
    for b in buckets:
        if b >= k:
            return b
    if clamp:
        return buckets[-1]
    return 1 << int(np.ceil(np.log2(max(k, 1))))


def pad_roots(roots: np.ndarray, bucket: int):
    """Pad a host root batch to ``bucket``: (roots [bucket], valid [bucket])."""
    B = len(roots)
    proots = np.zeros(bucket, np.int32)
    proots[:B] = roots
    bvalid = np.zeros(bucket, bool)
    bvalid[:B] = True
    return proots, bvalid


# ------------------------------------------------------------------ routing
def route_plan(dest: jax.Array, n: int, cap: int):
    """Slot assignment for routing M items into [n, cap] peer buckets.

    Returns (slot [M] — each input's peer*cap+rank, or OOB when dropped,
    kept [M], overflow — the count of *valid* (0 <= dest < n) items dropped
    because their peer bucket overflowed ``cap``). Items with a dest outside
    [0, n) are dropped silently (padding), not counted as overflow.
    """
    M = dest.shape[0]
    order = jnp.argsort(dest)
    sd = dest[order]
    offs = jnp.searchsorted(sd, jnp.arange(n, dtype=dest.dtype), side="left")
    rank = jnp.arange(M) - offs[jnp.clip(sd, 0, n - 1)]
    keep_sorted = (rank < cap) & (sd >= 0) & (sd < n)
    slot_sorted = jnp.where(keep_sorted, sd * cap + rank, n * cap)
    slot = jnp.full((M,), n * cap, jnp.int32)
    slot = slot.at[order].set(slot_sorted.astype(jnp.int32), mode="drop")
    kept = slot < n * cap
    overflow = jnp.sum(((dest >= 0) & (dest < n) & ~kept).astype(jnp.int32))
    return slot, kept, overflow


def route_scatter(vals: jax.Array, slot: jax.Array, n: int, cap: int, fill=NULL_ID):
    """Place ``vals`` into the [n, cap] send buckets of a ``route_plan``."""
    buckets = jnp.full((n * cap,) + vals.shape[1:], fill, vals.dtype)
    return buckets.at[slot].set(vals, mode="drop").reshape((n, cap) + vals.shape[1:])


def bucketize(vals, dest, n, cap, fill=NULL_ID):
    """Route ``vals`` into [n, cap] peer buckets (MoE-dispatch style).

    Returns (buckets [n, cap], slot, kept, overflow); see ``route_plan``.
    """
    slot, kept, overflow = route_plan(dest, n, cap)
    return route_scatter(vals, slot, n, cap, fill), slot, kept, overflow


def compact_rows(mask: jax.Array, cap: int, arrays, fills):
    """Order-preserving row compaction of parallel arrays to ``cap`` rows.

    Returns (compacted arrays, n kept, overflow — masked rows dropped past
    ``cap``). Used to shrink the mostly-masked cache-maintenance op stream
    before it is routed between shards. One index scatter over the M-row
    stream builds a gather map, so each of the k columns costs only a
    ``cap``-row gather instead of its own M-row scatter.
    """
    mask = mask.astype(bool)
    M = mask.shape[0]
    idx = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask, idx, cap)
    sel = jnp.full((cap,), M, jnp.int32).at[dest].set(
        jnp.arange(M, dtype=jnp.int32), mode="drop"
    )
    live = sel < M
    selc = jnp.clip(sel, 0, M - 1)
    outs = []
    for a, fill in zip(arrays, fills):
        got = a[selc]
        m = live.reshape((cap,) + (1,) * (a.ndim - 1))
        outs.append(jnp.where(m, got, jnp.asarray(fill, a.dtype)))
    total = jnp.sum(mask.astype(jnp.int32))
    n = jnp.minimum(total, cap)
    return outs, n, total - n


# --------------------------------------------------------------- miss exec
def onehop_exec_view(
    espec,
    view,
    direction: int,
    edge_label: int,
    pr,
    pe,
    pl,
    roots: jax.Array,  # int32 [B]
    params: jax.Array,  # int32 [B, PARAM_LEN]
    rmask: jax.Array,  # bool [B]
):
    """Execute one one-hop sub-query instance per root (the cache-miss path)
    against a storage ``view`` — the full replicated store
    (``GlobalStoreView``) or one shard's owner-local blocks
    (``partition.BlockStoreView``). Both views yield identical values for
    the same logical store, so this one function *is* both engines' miss
    path.

    Returns (leaves [B, RW], lmask, n_true [B], truncated [B], stats) where
    RW = espec.result_width. ``n_true`` is the un-truncated cardinality and
    ``truncated`` flags supernode rows whose adjacency exceeded the gather
    window — neither is cacheable when truncated.
    """
    pe_bound = params[:, :MAX_CONDS]
    pl_bound = params[:, MAX_CONDS:]

    rlab = take_along0(view.vlabel, roots)
    rprops = take_along0(view.vprops, roots)
    r_ok = evaluate_pred(pr, rlab, rprops) & rmask

    leaf_parts, mask_parts, el_parts, ep_parts = [], [], [], []
    trunc = jnp.zeros_like(r_ok)
    if direction in (DIR_OUT, DIR_BOTH):
        o, m, t, el, epr = view.adjacency(roots, espec.max_deg, incoming=False)
        leaf_parts.append(o), mask_parts.append(m)
        el_parts.append(el), ep_parts.append(epr)
        trunc |= t
    if direction in (DIR_IN, DIR_BOTH):
        o, m, t, el, epr = view.adjacency(roots, espec.max_deg, incoming=True)
        leaf_parts.append(o), mask_parts.append(m)
        el_parts.append(el), ep_parts.append(epr)
        trunc |= t
    leaf = jnp.concatenate(leaf_parts, axis=1)
    # gate the observed-edge mask by rmask so per-row stats only count rows
    # this call was actually asked to execute (padded / hit-short-circuited
    # rows must not contribute phantom scans)
    scanned_mask = jnp.concatenate(mask_parts, axis=1) & rmask[:, None]
    mask = scanned_mask
    n_edges_scanned = jnp.sum(mask.astype(jnp.int32))

    elab = jnp.concatenate(el_parts, axis=1)
    ep = jnp.concatenate(ep_parts, axis=1)
    e_ok = (edge_label < 0) | (elab == edge_label)
    e_ok &= evaluate_pred(pe, elab, ep, bound_vals=pe_bound[:, None, :])
    mask &= e_ok
    n_leaf_fetches = jnp.sum(mask.astype(jnp.int32))  # the paper's "n"

    llab = take_along0(view.vlabel, leaf)
    lp = take_along0(view.vprops, leaf)
    l_ok = evaluate_pred(pl, llab, lp, bound_vals=pl_bound[:, None, :])
    mask &= l_ok & r_ok[:, None]

    mask = dedup_masked(leaf, mask)  # set semantics (Definition 2.1)
    n_true = jnp.sum(mask.astype(jnp.int32), axis=1)
    leaves, lmask = compact_masked(leaf, mask, espec.result_width)
    stats = {
        "edges_scanned": n_edges_scanned,
        "leaf_fetches": n_leaf_fetches,
        # full read-conflict set for OCC population commits: every vertex
        # whose state this execution *observed*, including filtered-out
        # leaves (their property writes can change the result too)
        "scanned": leaf,
        "scanned_mask": scanned_mask,
    }
    return leaves, lmask, n_true, trunc & rmask, stats


def onehop_exec(
    espec,
    store,
    direction: int,
    edge_label: int,
    pr,
    pe,
    pl,
    roots: jax.Array,
    params: jax.Array,
    rmask: jax.Array,
):
    """``onehop_exec_view`` against a full ``GraphStore`` (single-host)."""
    return onehop_exec_view(
        espec, GlobalStoreView(espec.store, store), direction, edge_label,
        pr, pe, pl, roots, params, rmask,
    )


class MissRecord(NamedTuple):
    """Host-side record of one cache miss awaiting async population."""

    tpl_idx: int
    root: int
    params: np.ndarray  # int32 [PARAM_LEN]
    read_version: int


# ----------------------------------------------------------- fused pipeline
def make_hop_kernel(espec, hop, use_cache: bool, exec_fn=None, defer_fn=None):
    """One hop of the fused pipeline over a flat root frontier.

    Returns ``kernel(store, cache, ttable, roots_flat, rmask_flat,
    params_flat=None) ->
    (vals [BF, RW], cnt [BF], miss_roots [BF], n_miss_records, stats)``.
    ``params_flat`` is the per-row bound predicate params ([BF, PARAM_LEN]);
    the sharded tier unpacks it from the routed query frame, the single
    host leaves it None and the hop's own params broadcast in place.
    ``(vals, cnt)`` are the hop's per-row results left-packed; everything
    the miss path touches — the storage gathers, hit/miss select, and
    miss-record compaction — lives behind a ``lax.cond``, so an all-hit
    frontier pays none of it. The sharded serve tier calls this kernel at
    the root's *owner* shard against the local cache shard; the single-host
    engine calls it in place. ``stats`` carries the device-side metric
    deltas (k = misses, n_read, hits, trunc, edges, leaves).

    ``exec_fn(store, roots, params, rmask)`` is the storage hook for the
    miss path (default: ``onehop_exec`` over a full ``GraphStore``; the
    partitioned tier supplies an owner-local block executor).

    ``defer_fn(roots_flat) -> bool[BF]`` is the degraded-mode hook: a
    traced per-row mask that is True where this shard cannot execute the
    row's miss — its storage is marked down, or (under cache-locality
    routing) the row was routed here for its *cache* home while its rows
    live at another shard. Misses there then **defer** instead of
    executing — cache hits still serve (the cache tier survives an
    owner's storage loss, and a locality-routed hit is the whole point),
    no storage gather runs, no miss record is emitted (CP must not
    populate from a lost block), and the deferred rows are encoded as
    ``cnt = -1`` so the home shard can flag them after unrouting. With
    the hook absent (single host) or the mask all-False (healthy mesh,
    no locality splits) the program is byte-identical to the
    non-degraded trace — degrading is an *input* change, not a recompile.
    """
    RW = espec.result_width
    cacheable = hop.tpl_idx >= 0 and use_cache
    if exec_fn is None:
        def exec_fn(store, roots_f, params, miss_m, hop=hop):
            return onehop_exec(
                espec, store, hop.direction, hop.edge_label,
                hop.pr, hop.pe, hop.pl, roots_f, params, miss_m,
            )

    def kernel(store, cache, ttable, roots_flat, rmask_flat, params_flat=None):
        BF = roots_flat.shape[0]
        if params_flat is None:
            params = jnp.broadcast_to(
                jnp.asarray(hop.params, jnp.int32), (BF, PARAM_LEN)
            )
        else:
            params = params_flat
        if cacheable:
            # lean probe: raw cached rows + O(BF) validity counts
            # (no per-element mask/select on the hit path)
            hit, leaves_c, cnt_c, _ = cache_lookup_lean(
                espec.cache, cache, hop.tpl_idx, roots_flat, params
            )
            hit = hit & rmask_flat & ttable.read_enabled[hop.tpl_idx]
            cnt_c = jnp.where(hit, cnt_c, 0)
            n_read = jnp.sum(rmask_flat.astype(jnp.int32))
            n_hit = jnp.sum(hit.astype(jnp.int32))
        else:
            hit = jnp.zeros((BF,), bool)
            leaves_c = cnt_c = None
            n_read = n_hit = jnp.int32(0)
        miss_mask = rmask_flat & ~hit
        if defer_fn is not None:
            deferred = miss_mask & defer_fn(roots_flat)
            miss_mask = miss_mask & ~deferred
        else:
            deferred = jnp.zeros((BF,), bool)
        k = jnp.sum(miss_mask.astype(jnp.int32))

        def run_exec(args, hop=hop):
            roots_f, miss_m = args
            leaves_e, lmask_e, n_true, trunc, stats = exec_fn(
                store, roots_f, params, miss_m,
            )
            cnt_e = jnp.where(miss_m, jnp.minimum(n_true, RW), 0)
            if cacheable:
                vals = jnp.where(hit[:, None], leaves_c, leaves_e)
                cnt = jnp.where(hit, cnt_c, cnt_e)
                rec = miss_m & ~trunc & (n_true <= RW)
                mr, _ = compact_masked(roots_f, rec, BF)
                nrec = jnp.sum(rec.astype(jnp.int32))
            else:
                vals, cnt = leaves_e, cnt_e
                mr = jnp.full((BF,), NULL_ID, jnp.int32)
                nrec = jnp.int32(0)
            return (vals, cnt, mr, nrec,
                    jnp.sum(trunc.astype(jnp.int32)),
                    stats["edges_scanned"], stats["leaf_fetches"])

        def skip_exec(args):
            # the all-hit short circuit: no storage gathers at all
            if cacheable:
                vals, cnt = leaves_c, cnt_c
            else:
                vals = jnp.full((BF, RW), NULL_ID, jnp.int32)
                cnt = jnp.zeros((BF,), jnp.int32)
            return (vals, cnt,
                    jnp.full((BF,), NULL_ID, jnp.int32),
                    jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0))

        vals, cnt, mr, nrec, trunc_n, es, lf = jax.lax.cond(
            k > 0, run_exec, skip_exec, (roots_flat, miss_mask)
        )
        # deferred rows ride the count channel home as -1 (their cnt is 0
        # on both cond branches, so the encoding is unambiguous)
        cnt = jnp.where(deferred, jnp.int32(-1), cnt)
        stats = {
            "k": k, "n_read": n_read, "hits": n_hit,
            "trunc": trunc_n, "edges": es, "leaves": lf,
        }
        return vals, cnt, mr, nrec, stats

    return kernel


def finalize_frontier(plan, store, q_roots, leaves, lmask):
    """Apply a plan's post filter + final clause to the final frontier."""
    if plan.post_filter is not None:
        kind = plan.post_filter[0]
        if kind == "id_neq":
            lmask = lmask & (leaves != q_roots[:, None])
        elif kind == "prop_neq_root":
            pid = plan.post_filter[1]
            lp = take_along0(store.vprops, leaves)[..., pid]
            rp = take_along0(store.vprops, q_roots)[..., pid]
            lmask = lmask & (lp != rp[:, None])
    if plan.final == FINAL_COUNT:
        return jnp.sum(lmask.astype(jnp.int32), axis=1)
    if plan.final == FINAL_VALUES:
        vals = take_along0(store.vprops, leaves)[..., plan.final_prop]
        return jnp.where(lmask, vals, NULL_ID)
    return jnp.where(lmask, leaves, NULL_ID)


class LocalPlanTier:
    """The single-host instantiation of the shared hop driver: no routing,
    no collectives, storage is the full ``GraphStore``. Every hook is the
    identity, so ``make_plan_fn(..., LocalPlanTier())`` traces exactly the
    program the pre-driver fused pipeline traced."""

    routed = False
    # degraded-mode hooks: a single host has no owner to lose, so the plan
    # fn takes no extra inputs and nothing ever defers
    extra_inputs = 0

    def bind(self, *extra):
        pass

    def defer_fn(self):
        return None

    def exec_fn(self, hop):
        return None  # default: onehop_exec over the full store

    def route(self, hop_idx, A, roots_flat, rmask_flat, params_row):
        # no routing: rows stay home, per-row params stay implicit (None ->
        # the hop kernel broadcasts its own params)
        return roots_flat, rmask_flat, None, None, jnp.int32(0)

    def unroute(self, ctx, vals, cnt):
        return vals, cnt

    def psum(self, x):
        return x

    def pack_count(self, nrec):
        return nrec

    def reduce_metrics(self, m):
        return m


def make_plan_fn(espec, plan, use_cache: bool, tier, *, overlap: bool = False):
    """The ROADMAP's shared hop driver: the whole-plan device program —
    every hop's probe + masked miss-exec + frontier merge, the final clause,
    per-hop compact miss arrays, and device metrics — parameterized by a
    ``tier`` of route/storage hooks so the single-host engine and the
    sharded serve tier are structurally one function instead of
    hand-mirrored loops.

    Tier hooks: ``exec_fn(hop)`` supplies the miss-path storage executor
    (None → full-store ``onehop_exec``); ``route``/``unroute`` move frontier
    roots to their owners and results home (identity on a single host,
    all_to_all on a mesh); ``psum`` reduces batch-global quantities (the
    miss-phase gate must fire on *any* shard's miss); ``pack_count`` shapes
    per-hop miss counts (the sharded tier emits one segment per shard);
    ``reduce_metrics`` globalizes additive metrics. ``extra_inputs`` /
    ``bind`` / ``defer_fn`` are the degraded-mode hooks: a tier may declare
    extra traced inputs (the sharded tier takes a ``down: bool[n]`` owner
    mask), bind them at the top of the trace, and defer owner-down misses
    in the hop kernel — deferred slots come home as ``cnt = -1`` and are
    surfaced per row in the ``deferred`` output. Shape-polymorphic over
    the batch dimension (the caller pads to a ``BUCKETS`` bucket and jits).

    The per-hop collective profile is lean: ``route`` and ``unroute`` are
    each ONE exchange of a packed frame (see the wire-format constants at
    the top of this module), and the former per-hop ``psum`` miss gate is
    deferred — per-hop local miss counts are stacked under the ``_hop_k``
    metrics key and globalized together with the additive metrics in one
    ``reduce_metrics`` call after the hop loop, which on a mesh is a single
    all-reduce per plan instead of one per hop plus one per metric.

    ``overlap=True`` double-buffers the frontier: the batch is split into
    two row streams pipelined through the hop loop with a one-stage skew,
    so one stream's exchange is issued adjacent to the other stream's
    owner-local exec and the two can overlap under async collectives.
    The caller must guarantee an even per-shard batch (and size route caps
    for the half-batch); results are row-identical to the unoverlapped
    schedule when route caps don't drop (e.g. ``route_cap_factor=None``).
    """
    F, RW = espec.frontier, espec.result_width
    kernels = [
        make_hop_kernel(
            espec, hop, use_cache, tier.exec_fn(hop), tier.defer_fn()
        )
        for hop in plan.hops
    ]
    n_extra = getattr(tier, "extra_inputs", 0)

    H = len(plan.hops)

    def fused(store, cache, ttable, roots, bvalid, *extra):
        assert len(extra) == n_extra, (len(extra), n_extra)
        if n_extra:
            tier.bind(*extra)
        Bb = roots.shape[0]
        n_streams = 2 if overlap else 1
        assert Bb % n_streams == 0, (Bb, n_streams)
        Bs = Bb // n_streams
        z = jnp.int32(0)
        m = {
            "phases": jnp.int32(1),  # root index lookup (request 1)
            "requests": jnp.sum(bvalid.astype(jnp.int32)),
            "hits": z, "misses": z, "truncated": z,
            "leaf_fetches": z, "edges_scanned": z, "cache_reads": z,
            "deferred": z,
        }
        if tier.routed:
            m["route_overflow"] = z
        # telemetry tier: owner-side frontier occupancy (live routed rows
        # this shard probed/executed, summed over hops). Local-only until
        # ``reduce_metrics`` folds it into the per-owner stage block — the
        # key is popped there, so host-visible metrics are unchanged.
        stage_rows = getattr(tier, "stage_rows", False)
        if stage_rows:
            m["_frontier_rows"] = z
        # per-hop miss segments and local miss counts, in stream order
        miss_roots = [[] for _ in range(H)]
        miss_counts = [[] for _ in range(H)]
        hop_k = [z for _ in range(H)]

        def new_stream(r, bv):
            return {
                "frontier": jnp.full(
                    (r.shape[0], F), NULL_ID, jnp.int32
                ).at[:, 0].set(r),
                "fmask": jnp.zeros((r.shape[0], F), bool).at[:, 0].set(bv),
                "row_def": jnp.zeros((r.shape[0],), bool),
                # the occupied frontier is always a left-packed prefix, so
                # each hop only probes/executes the A slots that can be live
                # (1 for the root hop, then min(F, A*RW)) instead of the
                # full F-wide frontier
                "A": 1,
            }

        streams = [
            new_stream(roots[i * Bs:(i + 1) * Bs], bvalid[i * Bs:(i + 1) * Bs])
            for i in range(n_streams)
        ]

        def stage_route(s, hop_idx):
            # ---- one packed exchange: frontier roots + flags + bound
            # params travel to their owner shards in a single frame
            # (identity on a single host) ----
            hop, A = plan.hops[hop_idx], s["A"]
            roots_flat = s["frontier"][:, :A].reshape(-1)
            rmask_flat = s["fmask"][:, :A].reshape(-1)
            q, qmask, qparams, ctx, ovf = tier.route(
                hop_idx, A, roots_flat, rmask_flat,
                jnp.asarray(hop.params, jnp.int32),
            )
            if tier.routed:
                m["route_overflow"] = m["route_overflow"] + ovf
            s["q"], s["qmask"], s["qparams"], s["ctx"] = q, qmask, qparams, ctx

        def stage_exec(s, hop_idx):
            # ---- owner-local probe + cond-gated miss execution ----
            hop, kernel = plan.hops[hop_idx], kernels[hop_idx]
            if stage_rows:
                m["_frontier_rows"] = m["_frontier_rows"] + jnp.sum(
                    s["qmask"].astype(jnp.int32))
            vals, cnt, mr, nrec, hs = kernel(
                store, cache, ttable, s["q"], s["qmask"], s["qparams"]
            )
            if hop.tpl_idx >= 0 and use_cache:
                m["requests"] = m["requests"] + hs["n_read"]
                m["cache_reads"] = m["cache_reads"] + hs["n_read"]
                m["hits"] = m["hits"] + hs["hits"]
                miss_roots[hop_idx].append(mr)
                miss_counts[hop_idx].append(tier.pack_count(nrec))
            # the miss-phase gate is structural (fires on *any* shard's
            # miss) — stash the local count; it globalizes with the other
            # metrics in the single deferred reduction below
            hop_k[hop_idx] = hop_k[hop_idx] + hs["k"]
            m["requests"] = m["requests"] + hs["k"] + hs["leaves"]
            m["leaf_fetches"] = m["leaf_fetches"] + hs["leaves"]
            m["edges_scanned"] = m["edges_scanned"] + hs["edges"]
            m["misses"] = m["misses"] + hs["k"]
            m["truncated"] = m["truncated"] + hs["trunc"]
            s["vals"], s["cnt"] = vals, cnt

        def stage_finish(s, hop_idx):
            # ---- one packed exchange home, then the home-shard on-device
            # dedup/compact merge (cost tracks occupancy) ----
            A, Br = s["A"], s["frontier"].shape[0]
            vals, cnt = tier.unroute(s["ctx"], s["vals"], s["cnt"])
            cnt = cnt.reshape(Br, A)
            # decode the deferred channel: any owner-down slot (cnt = -1)
            # marks the whole query row bounded-stale
            s["row_def"] = s["row_def"] | jnp.any(cnt < 0, axis=1)
            cnt = jnp.maximum(cnt, 0)
            s["frontier"], s["fmask"] = segmented_dedup_merge(
                vals.reshape(Br, A, RW), cnt, F
            )
            s["A"] = min(F, A * RW)

        if n_streams == 1:
            (s,) = streams
            for h in range(H):
                stage_route(s, h)
                stage_exec(s, h)
                stage_finish(s, h)
        else:
            # double-buffered schedule, one-stage skew: each exchange
            # (route/unroute) is issued adjacent to the *other* stream's
            # owner-local exec, so async collectives overlap miss work
            sa, sb = streams
            stage_route(sa, 0)
            for h in range(H):
                stage_exec(sa, h)
                stage_route(sb, h)       # b's hop-h exchange vs a's exec
                stage_finish(sa, h)
                if h + 1 < H:
                    stage_route(sa, h + 1)
                stage_exec(sb, h)        # b's exec vs a's hop-(h+1) exchange
                stage_finish(sb, h)

        for hop in plan.hops:
            if hop.tpl_idx >= 0 and use_cache:
                m["phases"] = m["phases"] + 1  # one cache get round-trip

        row_def = jnp.concatenate([s["row_def"] for s in streams])
        frontier = jnp.concatenate([s["frontier"] for s in streams])
        fmask = jnp.concatenate([s["fmask"] for s in streams])
        m["deferred"] = jnp.sum(row_def.astype(jnp.int32))
        result = finalize_frontier(plan, store, roots, frontier, fmask)
        if plan.post_filter is not None and plan.post_filter[0] != "id_neq":
            m["phases"] = m["phases"] + 1  # un-rewritten property fetch
            m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
        if plan.final == FINAL_VALUES:
            m["phases"] = m["phases"] + 1  # valueMap fetch
            m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
        m["phases"] = m["phases"] + plan.extra_phases
        # single deferred reduction: per-hop miss counts ride the metrics
        # dict through ``reduce_metrics`` (one all-reduce on a mesh), then
        # gate the per-hop edge-read + leaf-fetch phases on the global count
        m["_hop_k"] = jnp.stack(hop_k) if H else jnp.zeros((0,), jnp.int32)
        m = tier.reduce_metrics(m)
        k_g = m.pop("_hop_k")
        for h in range(H):
            m["phases"] = m["phases"] + 2 * (k_g[h] > 0)  # edge read + leaf fetch
        mr_out = tuple(
            seg[0] if len(seg) == 1 else jnp.concatenate(seg)
            for seg in miss_roots if seg
        )
        mc_out = tuple(
            c[0] if len(c) == 1 else
            jnp.concatenate([jnp.atleast_1d(x) for x in c])
            for c in miss_counts if c
        )
        return (result, row_def, mr_out, mc_out, m, store.version)

    return fused


def make_fused_plan_fn(espec, plan, use_cache: bool):
    """The single-host whole-plan fused device program (PR 2), now an
    instantiation of the shared hop driver with identity hooks."""
    return make_plan_fn(espec, plan, use_cache, LocalPlanTier())


def decode_miss_records(plan, use_cache, miss_roots, miss_counts, read_version):
    """Turn per-hop compact device miss arrays into host ``MissRecord``s.

    Each hop entry may hold several independently-counted segments (one per
    shard on the sharded runtime; a single segment on the single-host path):
    ``miss_roots[i]`` reshapes to [segments, L] with ``miss_counts[i]`` of
    shape [segments].
    """
    misses: list[MissRecord] = []
    ci = 0
    for hop in plan.hops:
        if hop.tpl_idx >= 0 and use_cache:
            counts = np.asarray(miss_counts[ci]).reshape(-1)
            segs = np.asarray(miss_roots[ci]).reshape(len(counts), -1)
            ci += 1
            params = np.asarray(hop.params, np.int32)
            for seg, cnt in zip(segs, counts):
                for r in seg[: int(cnt)]:
                    misses.append(
                        MissRecord(hop.tpl_idx, int(r), params, read_version)
                    )
    return misses


def host_compact_dedup(vals: np.ndarray, mask: np.ndarray, width: int):
    """Host-side per-row dedup + compaction (frontier merge between hops)."""
    B = vals.shape[0]
    out = np.full((B, width), NULL_ID, np.int32)
    omask = np.zeros((B, width), bool)
    for b in range(B):
        row = vals[b][mask[b]]
        if row.size:
            _, first = np.unique(row, return_index=True)
            row = row[np.sort(first)][:width]
            out[b, : len(row)] = row
            omask[b, : len(row)] = True
    return out, omask


# ---------------------------------------------------------------- gRW step
_GRW_STEPS: dict = {}


def get_grw_step(espec, policy: str = "write-around", *, ops_cap: int = 4096,
                 sweep_cap: int = 512):
    """The jitted gRW-Tx commit: apply mutations + maintain the cache.

    Both the graph writes and the cache maintenance happen in one functional
    state transition — the tensor analogue of FDB buffering both in one
    transaction commit (§4). The step is cached by ``(espec, policy,
    caps)`` so repeated ``run_grw_tx`` calls reuse one compiled program.

    The maintenance phase uses the sharded write path's *op-stream
    compaction* (backported): the mutation listener derives the impacted
    keys as tensor streams, the mostly-masked stream is compacted to
    ``ops_cap`` real ops, and only those are applied against the cache —
    the pre-compaction path instead probed the hash table for every masked
    lane of every emission (O(mutations x templates x gather-width) probes;
    the old gRW benchmark baseline). Sweeps and exact-key ops commute as
    applied (sweeps first, ops in emission order per key), reproducing the
    sequential listener semantics; ``repro.core.invalidation``'s sink-based
    appliers remain the behavioural reference the equivalence tests pin.

    Returns ``(store', cache', impacted, op_overflow)``; ``impacted``
    counts distinct logical cache entries removed (chunk-0 occupancy delta)
    and a nonzero ``op_overflow`` means real maintenance ops were dropped
    by the compaction caps — raise ``ops_cap``/``sweep_cap``.
    """
    key = (espec, policy, ops_cap, sweep_cap)
    if key not in _GRW_STEPS:
        from repro.core.invalidation import (
            CacheOpStream,
            SweepStream,
            apply_op_stream_batched,
            apply_op_stream_segmented,
            apply_sweeps,
            derive_cache_ops,
        )
        from repro.graphstore.mutations import apply_mutations

        through = policy != "write-around"
        cspec = espec.cache

        @jax.jit
        def step(store, cache, ttable, batch):
            store2, applied = apply_mutations(espec.store, store, batch)
            ops, sweeps = derive_cache_ops(
                espec, store, store2, ttable, applied, through=through
            )
            (okind, otpl, oroot, oparams, ovid, oorder), n_ops, ovf_o = compact_rows(
                ops.ok, ops_cap,
                (ops.kind, ops.tpl, ops.root, ops.params, ops.vid, ops.order),
                (0, -1, NULL_ID, 0, NULL_ID, 0),
            )
            cops = CacheOpStream(
                kind=okind, tpl=otpl, root=oroot, params=oparams, vid=ovid,
                order=oorder, ok=jnp.arange(ops_cap) < n_ops,
            )
            (stpl, sroot), n_sw, ovf_s = compact_rows(
                sweeps.ok, sweep_cap, (sweeps.tpl, sweeps.root), (-1, NULL_ID)
            )
            gsw = SweepStream(
                tpl=stpl, root=sroot, ok=jnp.arange(sweep_cap) < n_sw
            )
            head = lambda c: jnp.sum((c.valid & (c.chunk == 0)).astype(jnp.int32))
            occ0 = head(cache)
            cache2 = apply_sweeps(cspec, cache, gsw)
            if through:
                # value edits are order-sensitive per key; distinct keys
                # commute — the segmented apply vectorizes across them
                cache2 = apply_op_stream_segmented(cspec, cache2, cops)
            else:
                cache2 = apply_op_stream_batched(cspec, cache2, cops)
            impacted = occ0 - head(cache2)
            cache2 = cache2._replace(n_delete=cache.n_delete + impacted)
            return store2, cache2, impacted, ovf_o + ovf_s

        _GRW_STEPS[key] = step
    return _GRW_STEPS[key]
