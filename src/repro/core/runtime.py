"""The shared transaction-runtime substrate.

Both entry points of the system compile against this module: the
single-host ``GraphEngine`` (core/engine.py) and the sharded serve tier
(distributed/graph_serve.py). It owns everything that used to be duplicated
between them:

- ``onehop_exec``          — one one-hop sub-query instance per root (the
                             cache-miss path; Definition 2.1 semantics).
- ``make_hop_kernel``      — one hop of the fused gR-Tx pipeline: lean cache
                             probe + ``lax.cond``-gated masked miss
                             execution over a flat root frontier. The
                             sharded runtime runs this same kernel at the
                             *owner* shard after routing; the single-host
                             engine runs it in place.
- ``make_fused_plan_fn``   — the whole-plan fused pipeline (PR 2): all hops,
                             on-device frontier merges, final clause, device
                             metrics. The single-host engine jits this
                             directly; it is byte-identical to running the
                             hop kernels inside ``shard_map`` on a 1-shard
                             mesh.
- bucketing / padding      — ``BUCKETS`` / ``bucket_for`` / ``pad_roots``
                             (previously copied between ``GraphEngine`` and
                             ``CachePopulator``) and the MoE-style routing
                             primitives ``route_plan`` / ``route_scatter`` /
                             ``bucketize`` (previously private to
                             ``graph_serve``). ``bucketize`` surfaces an
                             *overflow count* — valid items dropped because
                             a peer bucket filled up — so serving tiers can
                             alert on silent truncation.
- ``get_grw_step``         — the jitted gRW-Tx commit (apply mutations +
                             cache maintenance in one functional state
                             transition), cached by ``(espec, policy)`` so
                             repeated ``run_grw_tx`` calls never re-trace.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import cache_lookup_lean
from repro.core.keys import PARAM_LEN
from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    evaluate_pred,
)
from repro.graphstore.store import GlobalStoreView
from repro.utils import (
    NULL_ID,
    compact_masked,
    dedup_masked,
    segmented_dedup_merge,
    take_along0,
)

# final-clause codes of a QueryPlan
FINAL_IDS, FINAL_COUNT, FINAL_VALUES = 0, 1, 2

# batch buckets: gR-Tx batches are padded to the next bucket so the jit
# cache stays small. ``CachePopulator`` uses the prefix ``BUCKETS[:4]``.
BUCKETS = (8, 32, 128, 512, 2048, 8192)


def bucket_for(k: int, buckets=BUCKETS, clamp: bool = False) -> int:
    """Smallest bucket >= k; next power of two (or, clamped, the largest
    bucket — the caller then chunks) beyond the table."""
    for b in buckets:
        if b >= k:
            return b
    if clamp:
        return buckets[-1]
    return 1 << int(np.ceil(np.log2(max(k, 1))))


def pad_roots(roots: np.ndarray, bucket: int):
    """Pad a host root batch to ``bucket``: (roots [bucket], valid [bucket])."""
    B = len(roots)
    proots = np.zeros(bucket, np.int32)
    proots[:B] = roots
    bvalid = np.zeros(bucket, bool)
    bvalid[:B] = True
    return proots, bvalid


# ------------------------------------------------------------------ routing
def route_plan(dest: jax.Array, n: int, cap: int):
    """Slot assignment for routing M items into [n, cap] peer buckets.

    Returns (slot [M] — each input's peer*cap+rank, or OOB when dropped,
    kept [M], overflow — the count of *valid* (0 <= dest < n) items dropped
    because their peer bucket overflowed ``cap``). Items with a dest outside
    [0, n) are dropped silently (padding), not counted as overflow.
    """
    M = dest.shape[0]
    order = jnp.argsort(dest)
    sd = dest[order]
    offs = jnp.searchsorted(sd, jnp.arange(n, dtype=dest.dtype), side="left")
    rank = jnp.arange(M) - offs[jnp.clip(sd, 0, n - 1)]
    keep_sorted = (rank < cap) & (sd >= 0) & (sd < n)
    slot_sorted = jnp.where(keep_sorted, sd * cap + rank, n * cap)
    slot = jnp.full((M,), n * cap, jnp.int32)
    slot = slot.at[order].set(slot_sorted.astype(jnp.int32), mode="drop")
    kept = slot < n * cap
    overflow = jnp.sum(((dest >= 0) & (dest < n) & ~kept).astype(jnp.int32))
    return slot, kept, overflow


def route_scatter(vals: jax.Array, slot: jax.Array, n: int, cap: int, fill=NULL_ID):
    """Place ``vals`` into the [n, cap] send buckets of a ``route_plan``."""
    buckets = jnp.full((n * cap,) + vals.shape[1:], fill, vals.dtype)
    return buckets.at[slot].set(vals, mode="drop").reshape((n, cap) + vals.shape[1:])


def bucketize(vals, dest, n, cap, fill=NULL_ID):
    """Route ``vals`` into [n, cap] peer buckets (MoE-dispatch style).

    Returns (buckets [n, cap], slot, kept, overflow); see ``route_plan``.
    """
    slot, kept, overflow = route_plan(dest, n, cap)
    return route_scatter(vals, slot, n, cap, fill), slot, kept, overflow


def compact_rows(mask: jax.Array, cap: int, arrays, fills):
    """Order-preserving row compaction of parallel arrays to ``cap`` rows.

    Returns (compacted arrays, n kept, overflow — masked rows dropped past
    ``cap``). Used to shrink the mostly-masked cache-maintenance op stream
    before it is routed between shards. One index scatter over the M-row
    stream builds a gather map, so each of the k columns costs only a
    ``cap``-row gather instead of its own M-row scatter.
    """
    mask = mask.astype(bool)
    M = mask.shape[0]
    idx = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask, idx, cap)
    sel = jnp.full((cap,), M, jnp.int32).at[dest].set(
        jnp.arange(M, dtype=jnp.int32), mode="drop"
    )
    live = sel < M
    selc = jnp.clip(sel, 0, M - 1)
    outs = []
    for a, fill in zip(arrays, fills):
        got = a[selc]
        m = live.reshape((cap,) + (1,) * (a.ndim - 1))
        outs.append(jnp.where(m, got, jnp.asarray(fill, a.dtype)))
    total = jnp.sum(mask.astype(jnp.int32))
    n = jnp.minimum(total, cap)
    return outs, n, total - n


# --------------------------------------------------------------- miss exec
def onehop_exec_view(
    espec,
    view,
    direction: int,
    edge_label: int,
    pr,
    pe,
    pl,
    roots: jax.Array,  # int32 [B]
    params: jax.Array,  # int32 [B, PARAM_LEN]
    rmask: jax.Array,  # bool [B]
):
    """Execute one one-hop sub-query instance per root (the cache-miss path)
    against a storage ``view`` — the full replicated store
    (``GlobalStoreView``) or one shard's owner-local blocks
    (``partition.BlockStoreView``). Both views yield identical values for
    the same logical store, so this one function *is* both engines' miss
    path.

    Returns (leaves [B, RW], lmask, n_true [B], truncated [B], stats) where
    RW = espec.result_width. ``n_true`` is the un-truncated cardinality and
    ``truncated`` flags supernode rows whose adjacency exceeded the gather
    window — neither is cacheable when truncated.
    """
    pe_bound = params[:, :MAX_CONDS]
    pl_bound = params[:, MAX_CONDS:]

    rlab = take_along0(view.vlabel, roots)
    rprops = take_along0(view.vprops, roots)
    r_ok = evaluate_pred(pr, rlab, rprops) & rmask

    leaf_parts, mask_parts, el_parts, ep_parts = [], [], [], []
    trunc = jnp.zeros_like(r_ok)
    if direction in (DIR_OUT, DIR_BOTH):
        o, m, t, el, epr = view.adjacency(roots, espec.max_deg, incoming=False)
        leaf_parts.append(o), mask_parts.append(m)
        el_parts.append(el), ep_parts.append(epr)
        trunc |= t
    if direction in (DIR_IN, DIR_BOTH):
        o, m, t, el, epr = view.adjacency(roots, espec.max_deg, incoming=True)
        leaf_parts.append(o), mask_parts.append(m)
        el_parts.append(el), ep_parts.append(epr)
        trunc |= t
    leaf = jnp.concatenate(leaf_parts, axis=1)
    # gate the observed-edge mask by rmask so per-row stats only count rows
    # this call was actually asked to execute (padded / hit-short-circuited
    # rows must not contribute phantom scans)
    scanned_mask = jnp.concatenate(mask_parts, axis=1) & rmask[:, None]
    mask = scanned_mask
    n_edges_scanned = jnp.sum(mask.astype(jnp.int32))

    elab = jnp.concatenate(el_parts, axis=1)
    ep = jnp.concatenate(ep_parts, axis=1)
    e_ok = (edge_label < 0) | (elab == edge_label)
    e_ok &= evaluate_pred(pe, elab, ep, bound_vals=pe_bound[:, None, :])
    mask &= e_ok
    n_leaf_fetches = jnp.sum(mask.astype(jnp.int32))  # the paper's "n"

    llab = take_along0(view.vlabel, leaf)
    lp = take_along0(view.vprops, leaf)
    l_ok = evaluate_pred(pl, llab, lp, bound_vals=pl_bound[:, None, :])
    mask &= l_ok & r_ok[:, None]

    mask = dedup_masked(leaf, mask)  # set semantics (Definition 2.1)
    n_true = jnp.sum(mask.astype(jnp.int32), axis=1)
    leaves, lmask = compact_masked(leaf, mask, espec.result_width)
    stats = {
        "edges_scanned": n_edges_scanned,
        "leaf_fetches": n_leaf_fetches,
        # full read-conflict set for OCC population commits: every vertex
        # whose state this execution *observed*, including filtered-out
        # leaves (their property writes can change the result too)
        "scanned": leaf,
        "scanned_mask": scanned_mask,
    }
    return leaves, lmask, n_true, trunc & rmask, stats


def onehop_exec(
    espec,
    store,
    direction: int,
    edge_label: int,
    pr,
    pe,
    pl,
    roots: jax.Array,
    params: jax.Array,
    rmask: jax.Array,
):
    """``onehop_exec_view`` against a full ``GraphStore`` (single-host)."""
    return onehop_exec_view(
        espec, GlobalStoreView(espec.store, store), direction, edge_label,
        pr, pe, pl, roots, params, rmask,
    )


class MissRecord(NamedTuple):
    """Host-side record of one cache miss awaiting async population."""

    tpl_idx: int
    root: int
    params: np.ndarray  # int32 [PARAM_LEN]
    read_version: int


# ----------------------------------------------------------- fused pipeline
def make_hop_kernel(espec, hop, use_cache: bool, exec_fn=None, defer_fn=None):
    """One hop of the fused pipeline over a flat root frontier.

    Returns ``kernel(store, cache, ttable, roots_flat, rmask_flat) ->
    (vals [BF, RW], cnt [BF], miss_roots [BF], n_miss_records, stats)``.
    ``(vals, cnt)`` are the hop's per-row results left-packed; everything
    the miss path touches — the storage gathers, hit/miss select, and
    miss-record compaction — lives behind a ``lax.cond``, so an all-hit
    frontier pays none of it. The sharded serve tier calls this kernel at
    the root's *owner* shard against the local cache shard; the single-host
    engine calls it in place. ``stats`` carries the device-side metric
    deltas (k = misses, n_read, hits, trunc, edges, leaves).

    ``exec_fn(store, roots, params, rmask)`` is the storage hook for the
    miss path (default: ``onehop_exec`` over a full ``GraphStore``; the
    partitioned tier supplies an owner-local block executor).

    ``defer_fn() -> bool`` is the degraded-mode hook: a traced scalar that
    is True when this shard's *storage* is marked down. Misses here then
    **defer** instead of executing — cache hits still serve (the cache
    tier survives an owner's storage loss), no storage gather runs, no
    miss record is emitted (CP must not populate from a lost block), and
    the deferred rows are encoded as ``cnt = -1`` so the home shard can
    flag them after unrouting. With the hook absent (single host) or the
    mask all-False (healthy mesh) the program is byte-identical to the
    non-degraded trace — degrading is an *input* change, not a recompile.
    """
    RW = espec.result_width
    cacheable = hop.tpl_idx >= 0 and use_cache
    if exec_fn is None:
        def exec_fn(store, roots_f, params, miss_m, hop=hop):
            return onehop_exec(
                espec, store, hop.direction, hop.edge_label,
                hop.pr, hop.pe, hop.pl, roots_f, params, miss_m,
            )

    def kernel(store, cache, ttable, roots_flat, rmask_flat):
        BF = roots_flat.shape[0]
        params = jnp.broadcast_to(
            jnp.asarray(hop.params, jnp.int32), (BF, PARAM_LEN)
        )
        if cacheable:
            # lean probe: raw cached rows + O(BF) validity counts
            # (no per-element mask/select on the hit path)
            hit, leaves_c, cnt_c, _ = cache_lookup_lean(
                espec.cache, cache, hop.tpl_idx, roots_flat, params
            )
            hit = hit & rmask_flat & ttable.read_enabled[hop.tpl_idx]
            cnt_c = jnp.where(hit, cnt_c, 0)
            n_read = jnp.sum(rmask_flat.astype(jnp.int32))
            n_hit = jnp.sum(hit.astype(jnp.int32))
        else:
            hit = jnp.zeros((BF,), bool)
            leaves_c = cnt_c = None
            n_read = n_hit = jnp.int32(0)
        miss_mask = rmask_flat & ~hit
        if defer_fn is not None:
            deferred = miss_mask & defer_fn()
            miss_mask = miss_mask & ~deferred
        else:
            deferred = jnp.zeros((BF,), bool)
        k = jnp.sum(miss_mask.astype(jnp.int32))

        def run_exec(args, hop=hop):
            roots_f, miss_m = args
            leaves_e, lmask_e, n_true, trunc, stats = exec_fn(
                store, roots_f,
                jnp.broadcast_to(
                    jnp.asarray(hop.params, jnp.int32),
                    (roots_f.shape[0], PARAM_LEN),
                ),
                miss_m,
            )
            cnt_e = jnp.where(miss_m, jnp.minimum(n_true, RW), 0)
            if cacheable:
                vals = jnp.where(hit[:, None], leaves_c, leaves_e)
                cnt = jnp.where(hit, cnt_c, cnt_e)
                rec = miss_m & ~trunc & (n_true <= RW)
                mr, _ = compact_masked(roots_f, rec, BF)
                nrec = jnp.sum(rec.astype(jnp.int32))
            else:
                vals, cnt = leaves_e, cnt_e
                mr = jnp.full((BF,), NULL_ID, jnp.int32)
                nrec = jnp.int32(0)
            return (vals, cnt, mr, nrec,
                    jnp.sum(trunc.astype(jnp.int32)),
                    stats["edges_scanned"], stats["leaf_fetches"])

        def skip_exec(args):
            # the all-hit short circuit: no storage gathers at all
            if cacheable:
                vals, cnt = leaves_c, cnt_c
            else:
                vals = jnp.full((BF, RW), NULL_ID, jnp.int32)
                cnt = jnp.zeros((BF,), jnp.int32)
            return (vals, cnt,
                    jnp.full((BF,), NULL_ID, jnp.int32),
                    jnp.int32(0), jnp.int32(0),
                    jnp.int32(0), jnp.int32(0))

        vals, cnt, mr, nrec, trunc_n, es, lf = jax.lax.cond(
            k > 0, run_exec, skip_exec, (roots_flat, miss_mask)
        )
        # deferred rows ride the count channel home as -1 (their cnt is 0
        # on both cond branches, so the encoding is unambiguous)
        cnt = jnp.where(deferred, jnp.int32(-1), cnt)
        stats = {
            "k": k, "n_read": n_read, "hits": n_hit,
            "trunc": trunc_n, "edges": es, "leaves": lf,
        }
        return vals, cnt, mr, nrec, stats

    return kernel


def finalize_frontier(plan, store, q_roots, leaves, lmask):
    """Apply a plan's post filter + final clause to the final frontier."""
    if plan.post_filter is not None:
        kind = plan.post_filter[0]
        if kind == "id_neq":
            lmask = lmask & (leaves != q_roots[:, None])
        elif kind == "prop_neq_root":
            pid = plan.post_filter[1]
            lp = take_along0(store.vprops, leaves)[..., pid]
            rp = take_along0(store.vprops, q_roots)[..., pid]
            lmask = lmask & (lp != rp[:, None])
    if plan.final == FINAL_COUNT:
        return jnp.sum(lmask.astype(jnp.int32), axis=1)
    if plan.final == FINAL_VALUES:
        vals = take_along0(store.vprops, leaves)[..., plan.final_prop]
        return jnp.where(lmask, vals, NULL_ID)
    return jnp.where(lmask, leaves, NULL_ID)


class LocalPlanTier:
    """The single-host instantiation of the shared hop driver: no routing,
    no collectives, storage is the full ``GraphStore``. Every hook is the
    identity, so ``make_plan_fn(..., LocalPlanTier())`` traces exactly the
    program the pre-driver fused pipeline traced."""

    routed = False
    # degraded-mode hooks: a single host has no owner to lose, so the plan
    # fn takes no extra inputs and nothing ever defers
    extra_inputs = 0

    def bind(self, *extra):
        pass

    def defer_fn(self):
        return None

    def exec_fn(self, hop):
        return None  # default: onehop_exec over the full store

    def route(self, hop_idx, A, roots_flat, rmask_flat):
        return roots_flat, rmask_flat, None, jnp.int32(0)

    def unroute(self, ctx, vals, cnt):
        return vals, cnt

    def psum(self, x):
        return x

    def pack_count(self, nrec):
        return nrec

    def reduce_metrics(self, m):
        return m


def make_plan_fn(espec, plan, use_cache: bool, tier):
    """The ROADMAP's shared hop driver: the whole-plan device program —
    every hop's probe + masked miss-exec + frontier merge, the final clause,
    per-hop compact miss arrays, and device metrics — parameterized by a
    ``tier`` of route/storage hooks so the single-host engine and the
    sharded serve tier are structurally one function instead of
    hand-mirrored loops.

    Tier hooks: ``exec_fn(hop)`` supplies the miss-path storage executor
    (None → full-store ``onehop_exec``); ``route``/``unroute`` move frontier
    roots to their owners and results home (identity on a single host,
    all_to_all on a mesh); ``psum`` reduces batch-global quantities (the
    miss-phase gate must fire on *any* shard's miss); ``pack_count`` shapes
    per-hop miss counts (the sharded tier emits one segment per shard);
    ``reduce_metrics`` globalizes additive metrics. ``extra_inputs`` /
    ``bind`` / ``defer_fn`` are the degraded-mode hooks: a tier may declare
    extra traced inputs (the sharded tier takes a ``down: bool[n]`` owner
    mask), bind them at the top of the trace, and defer owner-down misses
    in the hop kernel — deferred slots come home as ``cnt = -1`` and are
    surfaced per row in the ``deferred`` output. Shape-polymorphic over
    the batch dimension (the caller pads to a ``BUCKETS`` bucket and jits).
    """
    F, RW = espec.frontier, espec.result_width
    kernels = [
        make_hop_kernel(
            espec, hop, use_cache, tier.exec_fn(hop), tier.defer_fn()
        )
        for hop in plan.hops
    ]
    n_extra = getattr(tier, "extra_inputs", 0)

    def fused(store, cache, ttable, roots, bvalid, *extra):
        assert len(extra) == n_extra, (len(extra), n_extra)
        if n_extra:
            tier.bind(*extra)
        Bb = roots.shape[0]
        frontier = jnp.full((Bb, F), NULL_ID, jnp.int32).at[:, 0].set(roots)
        fmask = jnp.zeros((Bb, F), bool).at[:, 0].set(bvalid)
        row_def = jnp.zeros((Bb,), bool)
        z = jnp.int32(0)
        m = {
            "phases": jnp.int32(1),  # root index lookup (request 1)
            "requests": jnp.sum(bvalid.astype(jnp.int32)),
            "hits": z, "misses": z, "truncated": z,
            "leaf_fetches": z, "edges_scanned": z, "cache_reads": z,
            "deferred": z,
        }
        if tier.routed:
            m["route_overflow"] = z
        miss_roots, miss_counts = [], []
        # the occupied frontier is always a left-packed prefix, so each hop
        # only probes/executes the A slots that can be live (1 for the root
        # hop, then min(F, A*RW)) instead of the full F-wide frontier
        A = 1
        for hop_idx, (hop, kernel) in enumerate(zip(plan.hops, kernels)):
            roots_flat = frontier[:, :A].reshape(-1)
            rmask_flat = fmask[:, :A].reshape(-1)
            # ---- route frontier roots to their owner shards (identity on
            # a single host) ----
            q, qmask, ctx, ovf = tier.route(hop_idx, A, roots_flat, rmask_flat)
            if tier.routed:
                m["route_overflow"] = m["route_overflow"] + ovf
            cacheable = hop.tpl_idx >= 0 and use_cache
            # ---- owner-local probe + cond-gated miss execution ----
            vals, cnt, mr, nrec, hs = kernel(store, cache, ttable, q, qmask)
            if cacheable:
                m["phases"] = m["phases"] + 1  # one cache get round-trip
                m["requests"] = m["requests"] + hs["n_read"]
                m["cache_reads"] = m["cache_reads"] + hs["n_read"]
                m["hits"] = m["hits"] + hs["hits"]
                miss_roots.append(mr)
                miss_counts.append(tier.pack_count(nrec))
            # phases are structural (identical on every shard), so the miss
            # gate uses the *global* miss count
            k_g = tier.psum(hs["k"])
            m["phases"] = m["phases"] + 2 * (k_g > 0)  # edge read + leaf fetch
            m["requests"] = m["requests"] + hs["k"] + hs["leaves"]
            m["leaf_fetches"] = m["leaf_fetches"] + hs["leaves"]
            m["edges_scanned"] = m["edges_scanned"] + hs["edges"]
            m["misses"] = m["misses"] + hs["k"]
            m["truncated"] = m["truncated"] + hs["trunc"]
            # ---- route the left-packed results home, then the home-shard
            # on-device dedup/compact merge (cost tracks occupancy) ----
            vals, cnt = tier.unroute(ctx, vals, cnt)
            cnt = cnt.reshape(Bb, A)
            # decode the deferred channel: any owner-down slot (cnt = -1)
            # marks the whole query row bounded-stale
            row_def = row_def | jnp.any(cnt < 0, axis=1)
            cnt = jnp.maximum(cnt, 0)
            frontier, fmask = segmented_dedup_merge(
                vals.reshape(Bb, A, RW), cnt, F
            )
            A = min(F, A * RW)

        m["deferred"] = jnp.sum(row_def.astype(jnp.int32))
        result = finalize_frontier(plan, store, roots, frontier, fmask)
        if plan.post_filter is not None and plan.post_filter[0] != "id_neq":
            m["phases"] = m["phases"] + 1  # un-rewritten property fetch
            m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
        if plan.final == FINAL_VALUES:
            m["phases"] = m["phases"] + 1  # valueMap fetch
            m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
        m["phases"] = m["phases"] + plan.extra_phases
        m = tier.reduce_metrics(m)
        return (result, row_def, tuple(miss_roots), tuple(miss_counts), m,
                store.version)

    return fused


def make_fused_plan_fn(espec, plan, use_cache: bool):
    """The single-host whole-plan fused device program (PR 2), now an
    instantiation of the shared hop driver with identity hooks."""
    return make_plan_fn(espec, plan, use_cache, LocalPlanTier())


def decode_miss_records(plan, use_cache, miss_roots, miss_counts, read_version):
    """Turn per-hop compact device miss arrays into host ``MissRecord``s.

    Each hop entry may hold several independently-counted segments (one per
    shard on the sharded runtime; a single segment on the single-host path):
    ``miss_roots[i]`` reshapes to [segments, L] with ``miss_counts[i]`` of
    shape [segments].
    """
    misses: list[MissRecord] = []
    ci = 0
    for hop in plan.hops:
        if hop.tpl_idx >= 0 and use_cache:
            counts = np.asarray(miss_counts[ci]).reshape(-1)
            segs = np.asarray(miss_roots[ci]).reshape(len(counts), -1)
            ci += 1
            params = np.asarray(hop.params, np.int32)
            for seg, cnt in zip(segs, counts):
                for r in seg[: int(cnt)]:
                    misses.append(
                        MissRecord(hop.tpl_idx, int(r), params, read_version)
                    )
    return misses


def host_compact_dedup(vals: np.ndarray, mask: np.ndarray, width: int):
    """Host-side per-row dedup + compaction (frontier merge between hops)."""
    B = vals.shape[0]
    out = np.full((B, width), NULL_ID, np.int32)
    omask = np.zeros((B, width), bool)
    for b in range(B):
        row = vals[b][mask[b]]
        if row.size:
            _, first = np.unique(row, return_index=True)
            row = row[np.sort(first)][:width]
            out[b, : len(row)] = row
            omask[b, : len(row)] = True
    return out, omask


# ---------------------------------------------------------------- gRW step
_GRW_STEPS: dict = {}


def get_grw_step(espec, policy: str = "write-around", *, ops_cap: int = 4096,
                 sweep_cap: int = 512):
    """The jitted gRW-Tx commit: apply mutations + maintain the cache.

    Both the graph writes and the cache maintenance happen in one functional
    state transition — the tensor analogue of FDB buffering both in one
    transaction commit (§4). The step is cached by ``(espec, policy,
    caps)`` so repeated ``run_grw_tx`` calls reuse one compiled program.

    The maintenance phase uses the sharded write path's *op-stream
    compaction* (backported): the mutation listener derives the impacted
    keys as tensor streams, the mostly-masked stream is compacted to
    ``ops_cap`` real ops, and only those are applied against the cache —
    the pre-compaction path instead probed the hash table for every masked
    lane of every emission (O(mutations x templates x gather-width) probes;
    the old gRW benchmark baseline). Sweeps and exact-key ops commute as
    applied (sweeps first, ops in emission order per key), reproducing the
    sequential listener semantics; ``repro.core.invalidation``'s sink-based
    appliers remain the behavioural reference the equivalence tests pin.

    Returns ``(store', cache', impacted, op_overflow)``; ``impacted``
    counts distinct logical cache entries removed (chunk-0 occupancy delta)
    and a nonzero ``op_overflow`` means real maintenance ops were dropped
    by the compaction caps — raise ``ops_cap``/``sweep_cap``.
    """
    key = (espec, policy, ops_cap, sweep_cap)
    if key not in _GRW_STEPS:
        from repro.core.invalidation import (
            CacheOpStream,
            SweepStream,
            apply_op_stream_batched,
            apply_op_stream_segmented,
            apply_sweeps,
            derive_cache_ops,
        )
        from repro.graphstore.mutations import apply_mutations

        through = policy != "write-around"
        cspec = espec.cache

        @jax.jit
        def step(store, cache, ttable, batch):
            store2, applied = apply_mutations(espec.store, store, batch)
            ops, sweeps = derive_cache_ops(
                espec, store, store2, ttable, applied, through=through
            )
            (okind, otpl, oroot, oparams, ovid, oorder), n_ops, ovf_o = compact_rows(
                ops.ok, ops_cap,
                (ops.kind, ops.tpl, ops.root, ops.params, ops.vid, ops.order),
                (0, -1, NULL_ID, 0, NULL_ID, 0),
            )
            cops = CacheOpStream(
                kind=okind, tpl=otpl, root=oroot, params=oparams, vid=ovid,
                order=oorder, ok=jnp.arange(ops_cap) < n_ops,
            )
            (stpl, sroot), n_sw, ovf_s = compact_rows(
                sweeps.ok, sweep_cap, (sweeps.tpl, sweeps.root), (-1, NULL_ID)
            )
            gsw = SweepStream(
                tpl=stpl, root=sroot, ok=jnp.arange(sweep_cap) < n_sw
            )
            head = lambda c: jnp.sum((c.valid & (c.chunk == 0)).astype(jnp.int32))
            occ0 = head(cache)
            cache2 = apply_sweeps(cspec, cache, gsw)
            if through:
                # value edits are order-sensitive per key; distinct keys
                # commute — the segmented apply vectorizes across them
                cache2 = apply_op_stream_segmented(cspec, cache2, cops)
            else:
                cache2 = apply_op_stream_batched(cspec, cache2, cops)
            impacted = occ0 - head(cache2)
            cache2 = cache2._replace(n_delete=cache.n_delete + impacted)
            return store2, cache2, impacted, ovf_o + ovf_s

        _GRW_STEPS[key] = step
    return _GRW_STEPS[key]
