"""gR-Tx processing with the one-hop sub-query result cache (§3.1).

A ``QueryPlan`` is the engine's IR for a Gremlin read: a chain of one-hop
hops (Definition 2.1) plus a final clause. Processing follows the paper
exactly: per hop, construct the cache keys for the current frontier, probe
the cache, execute *only the misses* against the storage manager, enqueue
misses for asynchronous population, and feed the union of leaf sets to the
next hop.

Execution pipeline
------------------
The default path (``GraphEngine.run`` with ``fused=True``) executes a gR-Tx
batch as **one jitted device program per (plan, batch-bucket)**: every hop
fuses the cache probe (``cache_lookup_lean`` — raw rows + O(B) validity
counts), a masked miss-execution (``onehop_exec`` runs over the occupied
frontier prefix with hit rows short-circuited behind a ``lax.cond`` that
skips the storage gathers entirely when the whole frontier hits), and an
on-device dedup/compact frontier merge (``segmented_dedup_merge``, which
exploits the left-packed per-slot results so merge cost tracks frontier
*occupancy*). Results, per-hop compact miss arrays, metrics, and the read
version come back in a **single device→host transfer per batch**
(``metrics["host_syncs"]``), so a 3-hop gR-Tx pays one sync instead of ~6.

The pipeline itself lives in the shared transaction runtime
(``repro.core.runtime``): both engines are instantiations of one hop driver
(``make_plan_fn``) over tier hooks — ``GraphEngine`` jits the identity-hook
``make_fused_plan_fn``, and the sharded serve tier
(``repro.distributed.graph_serve``) runs the same driver inside
``shard_map`` with owner routing between hops and (by default) the
partitioned dual-CSR storage tier under the miss path. The single-host
engine is the 1-shard special case of that runtime, and the two are tested
byte-identical on either storage tier.

Tradeoff: when *any* row of a hop misses, the fused path executes the
storage gathers over the whole occupied frontier with hit rows masked
(jit shapes cannot depend on the miss count), whereas the host path
compacts the k misses into a small bucket first. The fused default
therefore wins on the high-hit-rate steady state the paper targets (and
on accelerators, where masked lanes are cheap) but can do more device
work than ``fused=False`` on miss-heavy CPU workloads.

The legacy host-orchestrated path (``fused=False``) keeps the original
split — jitted probe / exec / final steps glued by host-side boolean
routing and a Python per-row frontier merge. It is retained as the
behavioural reference: the fused pipeline is tested byte-identical against
it (results, miss records, and metrics), and it remains the fallback for
debugging device-side issues. Both paths produce identical results; only
``host_syncs`` differs.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheSpec, CacheState, cache_lookup
from repro.core.keys import PARAM_LEN
from repro.core.runtime import (
    BUCKETS,
    FINAL_COUNT,
    FINAL_IDS,
    FINAL_VALUES,
    MissRecord,
    bucket_for,
    decode_miss_records,
    finalize_frontier,
    get_grw_step,
    host_compact_dedup as _host_compact_dedup,
    make_fused_plan_fn,
    onehop_exec,
    pad_roots,
)
from repro.core.templates import PredSpec, TemplateTable
from repro.graphstore.store import GraphStore, StoreSpec
from repro.graphstore.mutations import MutationBatch
from repro.utils import NULL_ID

__all__ = [
    "FINAL_IDS", "FINAL_COUNT", "FINAL_VALUES", "EngineSpec", "Hop",
    "QueryPlan", "MissRecord", "GraphEngine", "onehop_exec",
    "run_gr_tx_batch", "build_grw_step", "run_grw_tx",
]


class EngineSpec(NamedTuple):
    store: StoreSpec
    cache: CacheSpec
    max_deg: int = 64  # padded adjacency width per hop
    frontier: int = 64  # per-query frontier width between hops

    @property
    def result_width(self) -> int:
        # must equal the cache's value capacity so that any result the
        # engine can produce is either fully cacheable or flagged oversize
        return self.cache.max_leaves * self.cache.max_chunks


class Hop(NamedTuple):
    """One one-hop sub-query instance in a plan (template + bound params)."""

    direction: int  # DIR_OUT / DIR_IN / DIR_BOTH (static)
    edge_label: int  # static; ANY_LABEL = -1
    pr: PredSpec
    pe: PredSpec
    pl: PredSpec
    tpl_idx: int  # index into the TemplateTable; -1 = not cacheable
    params: np.ndarray  # int32 [PARAM_LEN] concrete wildcard values


class QueryPlan(NamedTuple):
    hops: tuple
    final: int = FINAL_IDS
    final_prop: int = -1  # for FINAL_VALUES
    # post filter over the final frontier:
    #   ("prop_neq_root", pid): drop leaves whose prop equals the root's
    #       prop value — costs one extra storage phase (property fetch).
    #   ("id_neq",): drop leaves equal to the root id — free (§4.2 rewrite).
    post_filter: Optional[tuple] = None
    # extra non-one-hop storage phases this query performs regardless of the
    # cache (Amdahl's 1-f portion; e.g. the aggregate query of Lesson 3)
    extra_phases: int = 0


class GraphEngine:
    """One Graph-QP: pre-jitted device programs for one plan.

    ``fused=True`` (default): one jitted program per batch bucket executes
    the whole plan — probe, masked miss-exec, on-device frontier merge — and
    all hops, with a single device→host transfer for the batch.
    ``fused=False``: the legacy host-orchestrated probe/exec/final steps.
    """

    _BUCKETS = BUCKETS

    def __init__(self, espec: EngineSpec, plan: QueryPlan, use_cache: bool = True,
                 fused: bool = True):
        assert espec.result_width >= 1
        self.espec = espec
        self.plan = plan
        self.use_cache = use_cache
        self.fused = fused
        self._probe_fns = {}
        self._exec_fns = {}
        self._final_fn = None
        # one jitted program; jax re-specializes per batch bucket
        self._fused_fn = jax.jit(make_fused_plan_fn(espec, plan, use_cache))

    # ---------------- jitted step builders ----------------
    def _probe(self, hop_idx: int):
        if hop_idx not in self._probe_fns:
            hop = self.plan.hops[hop_idx]
            espec = self.espec

            @jax.jit
            def probe(cache: CacheState, ttable: TemplateTable, roots, rmask):
                params = jnp.broadcast_to(
                    jnp.asarray(hop.params, jnp.int32), (roots.shape[0], PARAM_LEN)
                )
                hit, leaves, lmask, version = cache_lookup(
                    espec.cache, cache, hop.tpl_idx, roots, params
                )
                enabled = ttable.read_enabled[hop.tpl_idx]
                hit = hit & rmask & enabled
                return hit, leaves, lmask & hit[:, None]

            self._probe_fns[hop_idx] = probe
        return self._probe_fns[hop_idx]

    def _exec(self, hop_idx: int, bucket: int):
        key = (hop_idx, bucket)
        if key not in self._exec_fns:
            hop = self.plan.hops[hop_idx]
            espec = self.espec

            @jax.jit
            def exec_(store: GraphStore, roots, rmask):
                params = jnp.broadcast_to(
                    jnp.asarray(hop.params, jnp.int32), (roots.shape[0], PARAM_LEN)
                )
                return onehop_exec(
                    espec, store, hop.direction, hop.edge_label,
                    hop.pr, hop.pe, hop.pl, roots, params, rmask,
                )

            self._exec_fns[key] = exec_
        return self._exec_fns[key]

    def _final(self):
        if self._final_fn is None:
            plan = self.plan

            @jax.jit
            def final(store: GraphStore, q_roots, leaves, lmask):
                return finalize_frontier(plan, store, q_roots, leaves, lmask)

            self._final_fn = final
        return self._final_fn

    def _bucket_for(self, k: int) -> int:
        return bucket_for(k, self._BUCKETS)

    # ---------------- host orchestration ----------------
    def run(
        self,
        store: GraphStore,
        cache: CacheState,
        ttable: TemplateTable,
        roots: np.ndarray,
    ):
        """Process a batch of gR-Txs sharing this plan.

        Returns (result, misses: list[MissRecord], metrics: dict). The result
        array shape depends on the final clause. ``metrics["phases"]`` is the
        number of *sequential* storage round-trips the batch needed (the
        paper's n+2 → 2 effect); ``metrics["requests"]`` the total storage
        requests issued; ``metrics["host_syncs"]`` the number of blocking
        device→host transfer points the batch paid (1 on the fused path).
        """
        if self.fused:
            return self._run_fused(store, cache, ttable, roots)
        return self._run_host(store, cache, ttable, roots)

    def _run_fused(self, store, cache, ttable, roots):
        B = len(roots)
        bucket = self._bucket_for(B)
        proots, bvalid = pad_roots(roots, bucket)
        out = self._fused_fn(
            store, cache, ttable, jnp.asarray(proots), jnp.asarray(bvalid)
        )
        # the batch's single device->host synchronization point
        result, _deferred, miss_roots, miss_counts, m, version = (
            jax.device_get(out)
        )
        # _deferred is structurally always present (the sharded tier's
        # degraded mode flags owner-down rows there) but identically False
        # on a single host — nothing to surface beyond m["deferred"] == 0
        metrics = {k: int(v) for k, v in m.items()}
        metrics["host_syncs"] = 1
        misses = decode_miss_records(
            self.plan, self.use_cache, miss_roots, miss_counts, int(version)
        )
        return np.asarray(result)[:B], misses, metrics

    def _run_host(
        self,
        store: GraphStore,
        cache: CacheState,
        ttable: TemplateTable,
        roots: np.ndarray,
    ):
        """Legacy host-orchestrated path (reference; ``fused=False``)."""
        espec = self.espec
        B = len(roots)
        F = espec.frontier
        RW = espec.result_width
        read_version = int(store.version)

        frontier = np.full((B, F), NULL_ID, np.int32)
        frontier[:, 0] = roots
        fmask = np.zeros((B, F), bool)
        fmask[:, 0] = True

        misses: list[MissRecord] = []
        metrics = {
            "phases": 1,  # index lookup of the root vertex (paper's request 1)
            "requests": B,
            "hits": 0,
            "misses": 0,
            "truncated": 0,
            "leaf_fetches": 0,
            "edges_scanned": 0,
            "cache_reads": 0,
            "deferred": 0,  # degraded-mode rows: sharded-tier-only, kept
            "host_syncs": 1,  # for structural metric identity with fused
        }

        for hop_idx, hop in enumerate(self.plan.hops):
            roots_flat = frontier.reshape(-1)
            rmask_flat = fmask.reshape(-1)
            BF = roots_flat.shape[0]
            leaves_all = np.full((BF, RW), NULL_ID, np.int32)
            lmask_all = np.zeros((BF, RW), bool)

            cacheable = hop.tpl_idx >= 0 and self.use_cache
            if cacheable:
                hit, leaves_c, lmask_c = self._probe(hop_idx)(
                    cache, ttable, jnp.asarray(roots_flat), jnp.asarray(rmask_flat)
                )
                hit = np.asarray(hit)
                leaves_all[hit] = np.asarray(leaves_c)[hit]
                lmask_all[hit] = np.asarray(lmask_c)[hit]
                metrics["host_syncs"] += 1  # probe results block for routing
                metrics["phases"] += 1  # one cache get round-trip
                metrics["requests"] += int(rmask_flat.sum())
                metrics["cache_reads"] += int(rmask_flat.sum())
                metrics["hits"] += int(hit.sum())
            else:
                hit = np.zeros(BF, bool)

            miss_mask = rmask_flat & ~hit
            miss_idx = np.nonzero(miss_mask)[0]
            k = len(miss_idx)
            if k > 0:
                bucket = self._bucket_for(k)
                mroots = np.full(bucket, 0, np.int32)
                mroots[:k] = roots_flat[miss_idx]
                mvalid = np.zeros(bucket, bool)
                mvalid[:k] = True
                leaves_e, lmask_e, n_true, trunc, stats = self._exec(hop_idx, bucket)(
                    store, jnp.asarray(mroots), jnp.asarray(mvalid)
                )
                metrics["host_syncs"] += 1  # exec results block for the merge
                leaves_e = np.asarray(leaves_e)[:k]
                lmask_e = np.asarray(lmask_e)[:k]
                n_true = np.asarray(n_true)[:k]
                trunc = np.asarray(trunc)[:k]
                leaves_all[miss_idx] = leaves_e
                lmask_all[miss_idx] = lmask_e
                metrics["phases"] += 2  # edge range read + n leaf fetches
                metrics["requests"] += k + int(stats["leaf_fetches"])
                metrics["leaf_fetches"] += int(stats["leaf_fetches"])
                metrics["edges_scanned"] += int(stats["edges_scanned"])
                metrics["misses"] += k
                metrics["truncated"] += int(trunc.sum())
                if cacheable:
                    params = np.asarray(hop.params, np.int32)
                    for j, row in enumerate(miss_idx):
                        if not trunc[j] and n_true[j] <= RW:
                            misses.append(
                                MissRecord(hop.tpl_idx, int(roots_flat[row]), params, read_version)
                            )

            # next frontier: union of leaf sets per original query
            merged = leaves_all.reshape(B, F * RW)
            mmask = lmask_all.reshape(B, F * RW)
            nf, nm = _host_compact_dedup(merged, mmask, F)
            frontier, fmask = nf, nm

        result = self._final()(
            store, jnp.asarray(roots), jnp.asarray(frontier), jnp.asarray(fmask)
        )
        metrics["host_syncs"] += 1  # final result materialization
        if self.plan.post_filter is not None and self.plan.post_filter[0] != "id_neq":
            metrics["phases"] += 1  # property fetch for the un-rewritten filter
            metrics["requests"] += int(fmask.sum())
        if self.plan.final == FINAL_VALUES:
            metrics["phases"] += 1  # valueMap fetch
            metrics["requests"] += int(fmask.sum())
        metrics["phases"] += self.plan.extra_phases
        return np.asarray(result), misses, metrics


def run_gr_tx_batch(
    espec: EngineSpec,
    store: GraphStore,
    cache: CacheState,
    ttable: TemplateTable,
    plan: QueryPlan,
    roots: np.ndarray,
    use_cache: bool = True,
    fused: bool = True,
):
    """One-shot convenience wrapper (tests / examples)."""
    return GraphEngine(espec, plan, use_cache, fused=fused).run(store, cache, ttable, roots)


def build_grw_step(espec: EngineSpec, policy: str = "write-around", **caps):
    """The jitted gRW-Tx commit: apply mutations + maintain the cache, with
    the op-stream-compacted maintenance phase (the sharded write path's
    design, backported). ``step(store, cache, ttable, batch) -> (store',
    cache', impacted, op_overflow)``.

    Cached by ``(espec, policy, caps)`` in the shared runtime, so calling
    this (or ``run_grw_tx``) repeatedly reuses one compiled program instead
    of re-tracing per invocation. See ``repro.core.runtime.get_grw_step``.
    """
    return get_grw_step(espec, policy, **caps)


def run_grw_tx(
    espec: EngineSpec,
    store: GraphStore,
    cache: CacheState,
    ttable: TemplateTable,
    batch: MutationBatch,
    policy: str = "write-around",
):
    """One-shot gRW-Tx (tests / examples). Returns (store', cache', metrics)."""
    step = build_grw_step(espec, policy)
    store2, cache2, impacted, overflow = step(store, cache, ttable, batch)
    return store2, cache2, {
        "impacted_keys": int(impacted), "op_overflow": int(overflow),
    }
