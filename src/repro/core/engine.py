"""gR-Tx processing with the one-hop sub-query result cache (§3.1).

A ``QueryPlan`` is the engine's IR for a Gremlin read: a chain of one-hop
hops (Definition 2.1) plus a final clause. Processing follows the paper
exactly: per hop, construct the cache keys for the current frontier, probe
the cache, execute *only the misses* against the storage manager, enqueue
misses for asynchronous population, and feed the union of leaf sets to the
next hop.

Execution pipeline
------------------
The default path (``GraphEngine.run`` with ``fused=True``) executes a gR-Tx
batch as **one jitted device program per (plan, batch-bucket)**: every hop
fuses the cache probe (``cache_lookup_lean`` — raw rows + O(B) validity
counts), a masked miss-execution (``onehop_exec`` runs over the occupied
frontier prefix with hit rows short-circuited behind a ``lax.cond`` that
skips the storage gathers entirely when the whole frontier hits), and an
on-device dedup/compact frontier merge (``segmented_dedup_merge``, which
exploits the left-packed per-slot results so merge cost tracks frontier
*occupancy*; ``sort_dedup_masked`` is the sort-based general-mask variant,
used by the distributed serve step). Results, per-hop compact miss arrays,
metrics, and the read version come back in a **single device→host transfer
per batch** (``metrics["host_syncs"]``), so a 3-hop gR-Tx pays one sync
instead of ~6 — the prerequisite for pipelining hops across shards.
Batches are padded to power-of-two buckets so the jit cache stays small.

Tradeoff: when *any* row of a hop misses, the fused path executes the
storage gathers over the whole occupied frontier with hit rows masked
(jit shapes cannot depend on the miss count), whereas the host path
compacts the k misses into a small bucket first. The fused default
therefore wins on the high-hit-rate steady state the paper targets (and
on accelerators, where masked lanes are cheap) but can do more device
work than ``fused=False`` on miss-heavy CPU workloads.

The legacy host-orchestrated path (``fused=False``) keeps the original
split — jitted probe / exec / final steps glued by host-side boolean
routing and a Python per-row frontier merge. It is retained as the
behavioural reference: the fused pipeline is tested byte-identical against
it (results, miss records, and metrics), and it remains the fallback for
debugging device-side issues. Both paths produce identical results; only
``host_syncs`` differs.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheSpec, CacheState, cache_lookup, cache_lookup_lean
from repro.core.keys import PARAM_LEN
from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    PredSpec,
    TemplateTable,
    evaluate_pred,
)
from repro.graphstore.store import GraphStore, StoreSpec, gather_in, gather_out
from repro.graphstore.mutations import MutationBatch, apply_mutations
from repro.utils import (
    NULL_ID,
    compact_masked,
    dedup_masked,
    segmented_dedup_merge,
    take_along0,
)

FINAL_IDS, FINAL_COUNT, FINAL_VALUES = 0, 1, 2


class EngineSpec(NamedTuple):
    store: StoreSpec
    cache: CacheSpec
    max_deg: int = 64  # padded adjacency width per hop
    frontier: int = 64  # per-query frontier width between hops

    @property
    def result_width(self) -> int:
        # must equal the cache's value capacity so that any result the
        # engine can produce is either fully cacheable or flagged oversize
        return self.cache.max_leaves * self.cache.max_chunks


class Hop(NamedTuple):
    """One one-hop sub-query instance in a plan (template + bound params)."""

    direction: int  # DIR_OUT / DIR_IN / DIR_BOTH (static)
    edge_label: int  # static; ANY_LABEL = -1
    pr: PredSpec
    pe: PredSpec
    pl: PredSpec
    tpl_idx: int  # index into the TemplateTable; -1 = not cacheable
    params: np.ndarray  # int32 [PARAM_LEN] concrete wildcard values


class QueryPlan(NamedTuple):
    hops: tuple
    final: int = FINAL_IDS
    final_prop: int = -1  # for FINAL_VALUES
    # post filter over the final frontier:
    #   ("prop_neq_root", pid): drop leaves whose prop equals the root's
    #       prop value — costs one extra storage phase (property fetch).
    #   ("id_neq",): drop leaves equal to the root id — free (§4.2 rewrite).
    post_filter: Optional[tuple] = None
    # extra non-one-hop storage phases this query performs regardless of the
    # cache (Amdahl's 1-f portion; e.g. the aggregate query of Lesson 3)
    extra_phases: int = 0


def onehop_exec(
    espec: EngineSpec,
    store: GraphStore,
    direction: int,
    edge_label: int,
    pr: PredSpec,
    pe: PredSpec,
    pl: PredSpec,
    roots: jax.Array,  # int32 [B]
    params: jax.Array,  # int32 [B, PARAM_LEN]
    rmask: jax.Array,  # bool [B]
):
    """Execute one one-hop sub-query instance per root (the cache-miss path).

    Returns (leaves [B, RW], lmask, n_true [B], truncated [B], stats) where
    RW = espec.result_width. ``n_true`` is the un-truncated cardinality and
    ``truncated`` flags supernode rows whose adjacency exceeded the gather
    window — neither is cacheable when truncated.
    """
    sspec = espec.store
    pe_bound = params[:, :MAX_CONDS]
    pl_bound = params[:, MAX_CONDS:]

    rlab = take_along0(store.vlabel, roots)
    rprops = take_along0(store.vprops, roots)
    r_ok = evaluate_pred(pr, rlab, rprops) & rmask

    eids_parts, leaf_parts, mask_parts, trunc = [], [], [], jnp.zeros_like(r_ok)
    if direction in (DIR_OUT, DIR_BOTH):
        e, o, m, t = gather_out(sspec, store, roots, espec.max_deg)
        eids_parts.append(e), leaf_parts.append(o), mask_parts.append(m)
        trunc |= t
    if direction in (DIR_IN, DIR_BOTH):
        e, o, m, t = gather_in(sspec, store, roots, espec.max_deg)
        eids_parts.append(e), leaf_parts.append(o), mask_parts.append(m)
        trunc |= t
    eids = jnp.concatenate(eids_parts, axis=1)
    leaf = jnp.concatenate(leaf_parts, axis=1)
    # gate the observed-edge mask by rmask so per-row stats only count rows
    # this call was actually asked to execute (padded / hit-short-circuited
    # rows must not contribute phantom scans)
    scanned_mask = jnp.concatenate(mask_parts, axis=1) & rmask[:, None]
    mask = scanned_mask
    n_edges_scanned = jnp.sum(mask.astype(jnp.int32))

    elab = take_along0(store.elabel, eids)
    ep = take_along0(store.eprops, eids)
    e_ok = (edge_label < 0) | (elab == edge_label)
    e_ok &= evaluate_pred(pe, elab, ep, bound_vals=pe_bound[:, None, :])
    mask &= e_ok
    n_leaf_fetches = jnp.sum(mask.astype(jnp.int32))  # the paper's "n"

    llab = take_along0(store.vlabel, leaf)
    lp = take_along0(store.vprops, leaf)
    l_ok = evaluate_pred(pl, llab, lp, bound_vals=pl_bound[:, None, :])
    mask &= l_ok & r_ok[:, None]

    mask = dedup_masked(leaf, mask)  # set semantics (Definition 2.1)
    n_true = jnp.sum(mask.astype(jnp.int32), axis=1)
    leaves, lmask = compact_masked(leaf, mask, espec.result_width)
    stats = {
        "edges_scanned": n_edges_scanned,
        "leaf_fetches": n_leaf_fetches,
        # full read-conflict set for OCC population commits: every vertex
        # whose state this execution *observed*, including filtered-out
        # leaves (their property writes can change the result too)
        "scanned": leaf,
        "scanned_mask": scanned_mask,
    }
    return leaves, lmask, n_true, trunc & rmask, stats


class MissRecord(NamedTuple):
    """Host-side record of one cache miss awaiting async population."""

    tpl_idx: int
    root: int
    params: np.ndarray  # int32 [PARAM_LEN]
    read_version: int


class GraphEngine:
    """One Graph-QP: pre-jitted device programs for one plan.

    ``fused=True`` (default): one jitted program per batch bucket executes
    the whole plan — probe, masked miss-exec, on-device frontier merge — and
    all hops, with a single device→host transfer for the batch.
    ``fused=False``: the legacy host-orchestrated probe/exec/final steps.
    """

    _BUCKETS = (8, 32, 128, 512, 2048, 8192)

    def __init__(self, espec: EngineSpec, plan: QueryPlan, use_cache: bool = True,
                 fused: bool = True):
        assert espec.result_width >= 1
        self.espec = espec
        self.plan = plan
        self.use_cache = use_cache
        self.fused = fused
        self._probe_fns = {}
        self._exec_fns = {}
        self._final_fn = None
        self._fused_fns = {}

    # ---------------- jitted step builders ----------------
    def _probe(self, hop_idx: int):
        if hop_idx not in self._probe_fns:
            hop = self.plan.hops[hop_idx]
            espec = self.espec

            @jax.jit
            def probe(cache: CacheState, ttable: TemplateTable, roots, rmask):
                params = jnp.broadcast_to(
                    jnp.asarray(hop.params, jnp.int32), (roots.shape[0], PARAM_LEN)
                )
                hit, leaves, lmask, version = cache_lookup(
                    espec.cache, cache, hop.tpl_idx, roots, params
                )
                enabled = ttable.read_enabled[hop.tpl_idx]
                hit = hit & rmask & enabled
                return hit, leaves, lmask & hit[:, None]

            self._probe_fns[hop_idx] = probe
        return self._probe_fns[hop_idx]

    def _exec(self, hop_idx: int, bucket: int):
        key = (hop_idx, bucket)
        if key not in self._exec_fns:
            hop = self.plan.hops[hop_idx]
            espec = self.espec

            @jax.jit
            def exec_(store: GraphStore, roots, rmask):
                params = jnp.broadcast_to(
                    jnp.asarray(hop.params, jnp.int32), (roots.shape[0], PARAM_LEN)
                )
                return onehop_exec(
                    espec, store, hop.direction, hop.edge_label,
                    hop.pr, hop.pe, hop.pl, roots, params, rmask,
                )

            self._exec_fns[key] = exec_
        return self._exec_fns[key]

    def _final(self):
        if self._final_fn is None:
            plan, espec = self.plan, self.espec

            @jax.jit
            def final(store: GraphStore, q_roots, leaves, lmask):
                if plan.post_filter is not None:
                    kind = plan.post_filter[0]
                    if kind == "id_neq":
                        lmask = lmask & (leaves != q_roots[:, None])
                    elif kind == "prop_neq_root":
                        pid = plan.post_filter[1]
                        lp = take_along0(store.vprops, leaves)[..., pid]
                        rp = take_along0(store.vprops, q_roots)[..., pid]
                        lmask = lmask & (lp != rp[:, None])
                if plan.final == FINAL_COUNT:
                    return jnp.sum(lmask.astype(jnp.int32), axis=1)
                if plan.final == FINAL_VALUES:
                    vals = take_along0(store.vprops, leaves)[..., plan.final_prop]
                    return jnp.where(lmask, vals, NULL_ID)
                return jnp.where(lmask, leaves, NULL_ID)

            self._final_fn = final
        return self._final_fn

    # ---------------- fused device pipeline ----------------
    def _bucket_for(self, k: int) -> int:
        for b in self._BUCKETS:
            if b >= k:
                return b
        return 1 << int(np.ceil(np.log2(max(k, 1))))

    def _fused(self, bucket: int):
        """One jitted program: every hop's probe + masked miss-exec + merge,
        the final clause, per-hop compact miss arrays, and device metrics."""
        if bucket not in self._fused_fns:
            espec, plan, use_cache = self.espec, self.plan, self.use_cache
            F, RW = espec.frontier, espec.result_width

            @jax.jit
            def fused(store: GraphStore, cache: CacheState, ttable: TemplateTable,
                      roots, bvalid):
                Bb = roots.shape[0]
                frontier = jnp.full((Bb, F), NULL_ID, jnp.int32).at[:, 0].set(roots)
                fmask = jnp.zeros((Bb, F), bool).at[:, 0].set(bvalid)
                z = jnp.int32(0)
                m = {
                    "phases": jnp.int32(1),  # root index lookup (request 1)
                    "requests": jnp.sum(bvalid.astype(jnp.int32)),
                    "hits": z, "misses": z, "truncated": z,
                    "leaf_fetches": z, "edges_scanned": z, "cache_reads": z,
                }
                miss_roots, miss_counts = [], []
                # the occupied frontier is always a left-packed prefix, so
                # each hop only probes/executes the A slots that can be
                # live (1 for the root hop, then min(F, A*RW)) instead of
                # the full F-wide frontier
                A = 1
                for hop in plan.hops:
                    roots_flat = frontier[:, :A].reshape(-1)
                    rmask_flat = fmask[:, :A].reshape(-1)
                    BF = roots_flat.shape[0]
                    params = jnp.broadcast_to(
                        jnp.asarray(hop.params, jnp.int32), (BF, PARAM_LEN)
                    )
                    cacheable = hop.tpl_idx >= 0 and use_cache
                    if cacheable:
                        # lean probe: raw cached rows + O(BF) validity counts
                        # (no per-element mask/select on the hit path)
                        hit, leaves_c, cnt_c, _ = cache_lookup_lean(
                            espec.cache, cache, hop.tpl_idx, roots_flat, params
                        )
                        hit = hit & rmask_flat & ttable.read_enabled[hop.tpl_idx]
                        cnt_c = jnp.where(hit, cnt_c, 0)
                        n_read = jnp.sum(rmask_flat.astype(jnp.int32))
                        m["phases"] = m["phases"] + 1  # one cache get round-trip
                        m["requests"] = m["requests"] + n_read
                        m["cache_reads"] = m["cache_reads"] + n_read
                        m["hits"] = m["hits"] + jnp.sum(hit.astype(jnp.int32))
                    else:
                        hit = jnp.zeros((BF,), bool)
                        leaves_c = cnt_c = None
                    miss_mask = rmask_flat & ~hit
                    k = jnp.sum(miss_mask.astype(jnp.int32))

                    # (vals, counts) describe the hop's per-row results
                    # left-packed: everything the miss path touches — the
                    # storage gathers, hit/miss select, and miss-record
                    # compaction — lives behind the cond, so an all-hit
                    # frontier pays none of it.
                    def run_exec(args, hop=hop):
                        roots_f, miss_m = args
                        leaves_e, lmask_e, n_true, trunc, stats = onehop_exec(
                            espec, store, hop.direction, hop.edge_label,
                            hop.pr, hop.pe, hop.pl, roots_f,
                            jnp.broadcast_to(
                                jnp.asarray(hop.params, jnp.int32),
                                (roots_f.shape[0], PARAM_LEN),
                            ),
                            miss_m,
                        )
                        cnt_e = jnp.where(miss_m, jnp.minimum(n_true, RW), 0)
                        if cacheable:
                            vals = jnp.where(hit[:, None], leaves_c, leaves_e)
                            cnt = jnp.where(hit, cnt_c, cnt_e)
                            rec = miss_m & ~trunc & (n_true <= RW)
                            mr, _ = compact_masked(roots_f, rec, BF)
                            nrec = jnp.sum(rec.astype(jnp.int32))
                        else:
                            vals, cnt = leaves_e, cnt_e
                            mr = jnp.full((BF,), NULL_ID, jnp.int32)
                            nrec = jnp.int32(0)
                        return (vals, cnt, mr, nrec,
                                jnp.sum(trunc.astype(jnp.int32)),
                                stats["edges_scanned"], stats["leaf_fetches"])

                    def skip_exec(args):
                        # the all-hit short circuit: no storage gathers at all
                        if cacheable:
                            vals, cnt = leaves_c, cnt_c
                        else:
                            vals = jnp.full((BF, RW), NULL_ID, jnp.int32)
                            cnt = jnp.zeros((BF,), jnp.int32)
                        return (vals, cnt,
                                jnp.full((BF,), NULL_ID, jnp.int32),
                                jnp.int32(0), jnp.int32(0),
                                jnp.int32(0), jnp.int32(0))

                    vals, cnt, mr, nrec, trunc_n, es, lf = jax.lax.cond(
                        k > 0, run_exec, skip_exec, (roots_flat, miss_mask)
                    )
                    m["phases"] = m["phases"] + 2 * (k > 0)  # edge read + leaf fetches
                    m["requests"] = m["requests"] + k + lf
                    m["leaf_fetches"] = m["leaf_fetches"] + lf
                    m["edges_scanned"] = m["edges_scanned"] + es
                    m["misses"] = m["misses"] + k
                    m["truncated"] = m["truncated"] + trunc_n
                    if cacheable:
                        miss_roots.append(mr)
                        miss_counts.append(nrec)
                    # next frontier: on-device dedup/compact merge. Per-slot
                    # results are left-packed, so the count per segment fully
                    # describes validity and the merge cost tracks frontier
                    # *occupancy* (1-2 rounds typical) rather than its
                    # F*result_width capacity; matches the host merge
                    # exactly.
                    frontier, fmask = segmented_dedup_merge(
                        vals.reshape(Bb, A, RW), cnt.reshape(Bb, A), F
                    )
                    A = min(F, A * RW)

                leaves, lmask = frontier, fmask
                if plan.post_filter is not None:
                    kind = plan.post_filter[0]
                    if kind == "id_neq":
                        lmask = lmask & (leaves != roots[:, None])
                    elif kind == "prop_neq_root":
                        pid = plan.post_filter[1]
                        lp = take_along0(store.vprops, leaves)[..., pid]
                        rp = take_along0(store.vprops, roots)[..., pid]
                        lmask = lmask & (lp != rp[:, None])
                if plan.final == FINAL_COUNT:
                    result = jnp.sum(lmask.astype(jnp.int32), axis=1)
                elif plan.final == FINAL_VALUES:
                    vals = take_along0(store.vprops, leaves)[..., plan.final_prop]
                    result = jnp.where(lmask, vals, NULL_ID)
                else:
                    result = jnp.where(lmask, leaves, NULL_ID)
                if plan.post_filter is not None and plan.post_filter[0] != "id_neq":
                    m["phases"] = m["phases"] + 1  # un-rewritten property fetch
                    m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
                if plan.final == FINAL_VALUES:
                    m["phases"] = m["phases"] + 1  # valueMap fetch
                    m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
                m["phases"] = m["phases"] + plan.extra_phases
                return result, tuple(miss_roots), tuple(miss_counts), m, store.version

            self._fused_fns[bucket] = fused
        return self._fused_fns[bucket]

    # ---------------- host orchestration ----------------
    def run(
        self,
        store: GraphStore,
        cache: CacheState,
        ttable: TemplateTable,
        roots: np.ndarray,
    ):
        """Process a batch of gR-Txs sharing this plan.

        Returns (result, misses: list[MissRecord], metrics: dict). The result
        array shape depends on the final clause. ``metrics["phases"]`` is the
        number of *sequential* storage round-trips the batch needed (the
        paper's n+2 → 2 effect); ``metrics["requests"]`` the total storage
        requests issued; ``metrics["host_syncs"]`` the number of blocking
        device→host transfer points the batch paid (1 on the fused path).
        """
        if self.fused:
            return self._run_fused(store, cache, ttable, roots)
        return self._run_host(store, cache, ttable, roots)

    def _run_fused(self, store, cache, ttable, roots):
        B = len(roots)
        bucket = self._bucket_for(B)
        proots = np.zeros(bucket, np.int32)
        proots[:B] = roots
        bvalid = np.zeros(bucket, bool)
        bvalid[:B] = True
        out = self._fused(bucket)(
            store, cache, ttable, jnp.asarray(proots), jnp.asarray(bvalid)
        )
        # the batch's single device->host synchronization point
        result, miss_roots, miss_counts, m, version = jax.device_get(out)
        metrics = {k: int(v) for k, v in m.items()}
        metrics["host_syncs"] = 1
        read_version = int(version)
        misses: list[MissRecord] = []
        ci = 0
        for hop in self.plan.hops:
            if hop.tpl_idx >= 0 and self.use_cache:
                cnt = int(miss_counts[ci])
                mroots = miss_roots[ci]
                ci += 1
                params = np.asarray(hop.params, np.int32)
                for r in mroots[:cnt]:
                    misses.append(MissRecord(hop.tpl_idx, int(r), params, read_version))
        return np.asarray(result)[:B], misses, metrics

    def _run_host(
        self,
        store: GraphStore,
        cache: CacheState,
        ttable: TemplateTable,
        roots: np.ndarray,
    ):
        """Legacy host-orchestrated path (reference; ``fused=False``)."""
        espec = self.espec
        B = len(roots)
        F = espec.frontier
        RW = espec.result_width
        read_version = int(store.version)

        frontier = np.full((B, F), NULL_ID, np.int32)
        frontier[:, 0] = roots
        fmask = np.zeros((B, F), bool)
        fmask[:, 0] = True

        misses: list[MissRecord] = []
        metrics = {
            "phases": 1,  # index lookup of the root vertex (paper's request 1)
            "requests": B,
            "hits": 0,
            "misses": 0,
            "truncated": 0,
            "leaf_fetches": 0,
            "edges_scanned": 0,
            "cache_reads": 0,
            "host_syncs": 1,  # int(store.version) above
        }

        for hop_idx, hop in enumerate(self.plan.hops):
            roots_flat = frontier.reshape(-1)
            rmask_flat = fmask.reshape(-1)
            BF = roots_flat.shape[0]
            leaves_all = np.full((BF, RW), NULL_ID, np.int32)
            lmask_all = np.zeros((BF, RW), bool)

            cacheable = hop.tpl_idx >= 0 and self.use_cache
            if cacheable:
                hit, leaves_c, lmask_c = self._probe(hop_idx)(
                    cache, ttable, jnp.asarray(roots_flat), jnp.asarray(rmask_flat)
                )
                hit = np.asarray(hit)
                leaves_all[hit] = np.asarray(leaves_c)[hit]
                lmask_all[hit] = np.asarray(lmask_c)[hit]
                metrics["host_syncs"] += 1  # probe results block for routing
                metrics["phases"] += 1  # one cache get round-trip
                metrics["requests"] += int(rmask_flat.sum())
                metrics["cache_reads"] += int(rmask_flat.sum())
                metrics["hits"] += int(hit.sum())
            else:
                hit = np.zeros(BF, bool)

            miss_mask = rmask_flat & ~hit
            miss_idx = np.nonzero(miss_mask)[0]
            k = len(miss_idx)
            if k > 0:
                bucket = self._bucket_for(k)
                mroots = np.full(bucket, 0, np.int32)
                mroots[:k] = roots_flat[miss_idx]
                mvalid = np.zeros(bucket, bool)
                mvalid[:k] = True
                leaves_e, lmask_e, n_true, trunc, stats = self._exec(hop_idx, bucket)(
                    store, jnp.asarray(mroots), jnp.asarray(mvalid)
                )
                metrics["host_syncs"] += 1  # exec results block for the merge
                leaves_e = np.asarray(leaves_e)[:k]
                lmask_e = np.asarray(lmask_e)[:k]
                n_true = np.asarray(n_true)[:k]
                trunc = np.asarray(trunc)[:k]
                leaves_all[miss_idx] = leaves_e
                lmask_all[miss_idx] = lmask_e
                metrics["phases"] += 2  # edge range read + n leaf fetches
                metrics["requests"] += k + int(stats["leaf_fetches"])
                metrics["leaf_fetches"] += int(stats["leaf_fetches"])
                metrics["edges_scanned"] += int(stats["edges_scanned"])
                metrics["misses"] += k
                metrics["truncated"] += int(trunc.sum())
                if cacheable:
                    params = np.asarray(hop.params, np.int32)
                    for j, row in enumerate(miss_idx):
                        if not trunc[j] and n_true[j] <= RW:
                            misses.append(
                                MissRecord(hop.tpl_idx, int(roots_flat[row]), params, read_version)
                            )

            # next frontier: union of leaf sets per original query
            merged = leaves_all.reshape(B, F * RW)
            mmask = lmask_all.reshape(B, F * RW)
            nf, nm = _host_compact_dedup(merged, mmask, F)
            frontier, fmask = nf, nm

        result = self._final()(
            store, jnp.asarray(roots), jnp.asarray(frontier), jnp.asarray(fmask)
        )
        metrics["host_syncs"] += 1  # final result materialization
        if self.plan.post_filter is not None and self.plan.post_filter[0] != "id_neq":
            metrics["phases"] += 1  # property fetch for the un-rewritten filter
            metrics["requests"] += int(fmask.sum())
        if self.plan.final == FINAL_VALUES:
            metrics["phases"] += 1  # valueMap fetch
            metrics["requests"] += int(fmask.sum())
        metrics["phases"] += self.plan.extra_phases
        return np.asarray(result), misses, metrics


def _host_compact_dedup(vals: np.ndarray, mask: np.ndarray, width: int):
    """Host-side per-row dedup + compaction (frontier merge between hops)."""
    B = vals.shape[0]
    out = np.full((B, width), NULL_ID, np.int32)
    omask = np.zeros((B, width), bool)
    for b in range(B):
        row = vals[b][mask[b]]
        if row.size:
            _, first = np.unique(row, return_index=True)
            row = row[np.sort(first)][:width]
            out[b, : len(row)] = row
            omask[b, : len(row)] = True
    return out, omask


def run_gr_tx_batch(
    espec: EngineSpec,
    store: GraphStore,
    cache: CacheState,
    ttable: TemplateTable,
    plan: QueryPlan,
    roots: np.ndarray,
    use_cache: bool = True,
    fused: bool = True,
):
    """One-shot convenience wrapper (tests / examples)."""
    return GraphEngine(espec, plan, use_cache, fused=fused).run(store, cache, ttable, roots)


def build_grw_step(espec: EngineSpec, policy: str = "write-around"):
    """Build the jitted gRW-Tx commit: apply mutations + maintain the cache.

    Both the graph writes and the cache deletions happen in one functional
    state transition — the tensor analogue of FDB buffering both in one
    transaction commit (§4).
    """
    from repro.core.invalidation import invalidate_write_around, write_through_update

    @jax.jit
    def step(store: GraphStore, cache: CacheState, ttable: TemplateTable, batch: MutationBatch):
        store2, applied = apply_mutations(espec.store, store, batch)
        before = cache.n_delete
        if policy == "write-around":
            cache2 = invalidate_write_around(espec, store, store2, cache, ttable, applied)
        else:
            cache2 = write_through_update(espec, store, store2, cache, ttable, applied)
        impacted = cache2.n_delete - before
        return store2, cache2, impacted

    return step


def run_grw_tx(
    espec: EngineSpec,
    store: GraphStore,
    cache: CacheState,
    ttable: TemplateTable,
    batch: MutationBatch,
    policy: str = "write-around",
):
    """One-shot gRW-Tx (tests / examples). Returns (store', cache', metrics)."""
    step = build_grw_step(espec, policy)
    store2, cache2, impacted = step(store, cache, ttable, batch)
    return store2, cache2, {"impacted_keys": int(impacted)}
