"""Cache-key construction (§3).

A key identifies a unique one-hop sub-query instance:
``(template id, root vertex id, wildcard values of P^e, wildcard values of
P^l)``. We keep template id and root id *explicit* in the cache slot arrays
(so FDB's prefix ``clearRange`` becomes a vectorized sweep over the cache
partition — see cache.py), and reduce the parameter vector to a 32-bit
fingerprint plus an independently-seeded 32-bit slot hash (64 effective
bits; DESIGN.md §2 records the collision budget).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.templates import MAX_CONDS
from repro.utils import hash_rows

PARAM_LEN = 2 * MAX_CONDS  # P^e wildcards then P^l wildcards

_SEED_SLOT = 0x51ED5EED
_SEED_FP = 0xF1A9F00D


def make_param_vec(pe_wild_vals, pl_wild_vals):
    """Concatenate wildcard value vectors into the key's parameter vector."""
    return jnp.concatenate([pe_wild_vals, pl_wild_vals], axis=-1)


def _cols(tpl_id, root, params):
    tpl = jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), jnp.shape(root))
    cols = [tpl, jnp.asarray(root, jnp.int32)]
    for i in range(PARAM_LEN):
        cols.append(params[..., i])
    return cols


def key_slot_hash(tpl_id, root, params):
    """uint32 slot-selection hash of the full key tuple."""
    return hash_rows(_cols(tpl_id, root, params), _SEED_SLOT)


def key_fingerprint(tpl_id, root, params):
    """uint32 fingerprint over the *parameter* portion (tpl/root are stored
    explicitly in the slot, so the fingerprint only needs to disambiguate
    parameter vectors that collide in the probe window)."""
    return hash_rows(_cols(tpl_id, root, params), _SEED_FP)
