"""The one-hop sub-query result cache (§4), as a tensor hash table.

Physical design (the FDB-subspace analogue):

- Open-addressing table of ``capacity`` slots (power of two), linear probe
  window of ``probes`` slots. Template id and root vertex id are stored
  *explicitly* per slot; the parameter vector is fingerprinted. This keeps
  FDB's two key-prefix operations cheap:
    * ``clearRange(template)``        -> ``sweep_template`` (vectorized mask)
    * ``clearRange(template, root)``  -> ``sweep_root``
- Values are padded leaf-id rows of ``max_leaves``; results larger than one
  slot spill into continuation *chunks* (the paper's 100KB FDB value-size
  chunking) — chunk i of a key lives at an independent hash. Results larger
  than ``max_chunks * max_leaves`` are not cached (counted), mirroring the
  paper's supernode discussion.
- Inserts walk the batch sequentially (fori_loop): the insert path is the
  *write* path which the paper deliberately keeps off the read path, so
  serializing it costs reads nothing. Eviction policy: overwrite the last
  probe slot (documented FIFO-within-window; a cache may always drop).

Strong-consistency note: a fingerprint collision inside a probe window could
alias two different parameter vectors of the same (template, root). With 32b
slot-hash + 32b fingerprint + explicit (tpl, root) this is ~2^-64 per pair;
DESIGN.md §2 records the budget.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.keys import PARAM_LEN
from repro.utils import NULL_ID, hash_rows

_SEED_SLOT = 0x51ED5EED
_SEED_FP = 0xF1A9F00D


class CacheSpec(NamedTuple):
    capacity: int = 4096  # power of two
    probes: int = 8
    max_leaves: int = 32  # leaf ids per slot (one FDB value chunk)
    max_chunks: int = 2  # continuation chunks per key


class CacheState(NamedTuple):
    tpl: jax.Array  # int32 [cap] (-1 = never used)
    root: jax.Array  # int32 [cap]
    fp: jax.Array  # uint32 [cap]
    chunk: jax.Array  # int32 [cap]
    total_len: jax.Array  # int32 [cap] (authoritative on chunk 0)
    vals: jax.Array  # int32 [cap, max_leaves]
    version: jax.Array  # int32 [cap] commit version of the populating txn
    valid: jax.Array  # bool [cap]
    # stats (0-d int32): read hits / read misses / inserts / evictions /
    # deletes / oversize results skipped
    n_hit: jax.Array
    n_miss: jax.Array
    n_insert: jax.Array
    n_evict: jax.Array
    n_delete: jax.Array
    n_oversize: jax.Array


def empty_cache(spec: CacheSpec) -> CacheState:
    cap = spec.capacity
    assert cap & (cap - 1) == 0, "capacity must be a power of two"
    z = jnp.int32(0)
    return CacheState(
        tpl=jnp.full((cap,), -1, jnp.int32),
        root=jnp.full((cap,), -1, jnp.int32),
        fp=jnp.zeros((cap,), jnp.uint32),
        chunk=jnp.zeros((cap,), jnp.int32),
        total_len=jnp.zeros((cap,), jnp.int32),
        vals=jnp.full((cap, spec.max_leaves), NULL_ID, jnp.int32),
        version=jnp.zeros((cap,), jnp.int32),
        valid=jnp.zeros((cap,), bool),
        n_hit=z, n_miss=z, n_insert=z, n_evict=z, n_delete=z, n_oversize=z,
    )


def _key_cols(tpl_id, root, params, chunk):
    tpl = jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), jnp.shape(root))
    ch = jnp.broadcast_to(jnp.asarray(chunk, jnp.int32), jnp.shape(root))
    cols = [tpl, jnp.asarray(root, jnp.int32)]
    cols += [params[..., i] for i in range(PARAM_LEN)]
    cols.append(ch)
    return cols


def _probe(spec: CacheSpec, cache: CacheState, tpl_id, root, params, chunk):
    """Find the slot holding (tpl, root, params, chunk). Returns (found, slot)."""
    h = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_SLOT)
    fp = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_FP)
    base = (h & jnp.uint32(spec.capacity - 1)).astype(jnp.int32)
    offs = jnp.arange(spec.probes, dtype=jnp.int32)
    slots = (base[..., None] + offs) & (spec.capacity - 1)  # [..., P]
    match = (
        cache.valid[slots]
        & (cache.tpl[slots] == jnp.asarray(tpl_id, jnp.int32)[..., None])
        & (cache.root[slots] == jnp.asarray(root, jnp.int32)[..., None])
        & (cache.fp[slots] == fp[..., None])
        & (cache.chunk[slots] == chunk)
    )
    found = jnp.any(match, axis=-1)
    first = jnp.argmax(match, axis=-1)
    slot = jnp.where(found, jnp.take_along_axis(slots, first[..., None], -1)[..., 0], -1)
    return found, slot, slots, fp


def cache_lookup(spec: CacheSpec, cache: CacheState, tpl_id, root, params):
    """Batched read-path lookup (§3.1).

    Returns ``(hit [B], leaves [B, max_chunks*max_leaves], lmask, version)``.
    A hit requires chunk 0 plus every continuation chunk implied by
    ``total_len`` to be present (a partially-evicted chain is a miss).
    Stats are *not* updated here (pure read); the engine accumulates them.
    """
    L, C = spec.max_leaves, spec.max_chunks
    founds, slots = [], []
    for c in range(C):
        f, s, _, _ = _probe(spec, cache, tpl_id, root, params, c)
        founds.append(f)
        slots.append(s)
    found0 = founds[0]
    slot0 = slots[0]
    tlen = jnp.where(found0, cache.total_len[jnp.clip(slot0, 0)], 0)
    need = jnp.clip((tlen + L - 1) // L, 1, C)  # chunks required
    ok = found0
    for c in range(1, C):
        ok &= (need <= c) | founds[c]
    # chain consistency: continuation chunks must carry the same total_len
    for c in range(1, C):
        same = cache.total_len[jnp.clip(slots[c], 0)] == tlen
        ok &= (need <= c) | same
    leaves = jnp.concatenate(
        [cache.vals[jnp.clip(slots[c], 0)] for c in range(C)], axis=-1
    )
    pos = jnp.arange(L * C, dtype=jnp.int32)
    lmask = ok[..., None] & (pos < tlen[..., None])
    leaves = jnp.where(lmask, leaves, NULL_ID)
    version = jnp.where(ok, cache.version[jnp.clip(slot0, 0)], -1)
    return ok, leaves, lmask, version


def cache_insert(
    spec: CacheSpec,
    cache: CacheState,
    tpl_id,
    root,
    params,
    leaves,
    lens,
    commit_version,
    mask,
):
    """Write-path insert of B results (CP population / write-through).

    ``leaves``: int32 [B, >= max_chunks*max_leaves] compacted leaf ids.
    Sequential over the batch (see module docstring). Oversize results are
    skipped and counted.
    """
    L, C = spec.max_leaves, spec.max_chunks
    B = leaves.shape[0]
    width = leaves.shape[1]
    oversize = lens > L * C

    def body(i, cache):
        do = mask[i] & ~oversize[i]
        tlen = jnp.minimum(lens[i], L * C)
        nchunks = jnp.clip((tlen + L - 1) // L, 1, C)

        def write_chunk(c, cache):
            found, slot, slots, fp = _probe(
                spec, cache, tpl_id[i], root[i], params[i], c
            )
            empty = ~cache.valid[slots]
            has_empty = jnp.any(empty)
            first_empty = jnp.take_along_axis(
                slots, jnp.argmax(empty, -1)[None], -1
            )[0]
            # reuse matching slot, else first empty, else evict last probe
            target = jnp.where(found, slot, jnp.where(has_empty, first_empty, slots[-1]))
            evict = ~found & ~has_empty & cache.valid[target]
            active = do & (c < nchunks)
            t = jnp.where(active, target, spec.capacity)  # OOB -> drop
            seg = jax.lax.dynamic_slice(
                leaves[i], (c * L,), (L,)
            )
            seg = jnp.where(jnp.arange(L) < tlen - c * L, seg, NULL_ID)
            cache = cache._replace(
                tpl=cache.tpl.at[t].set(jnp.int32(tpl_id[i]), mode="drop"),
                root=cache.root.at[t].set(jnp.int32(root[i]), mode="drop"),
                fp=cache.fp.at[t].set(fp, mode="drop"),
                chunk=cache.chunk.at[t].set(c, mode="drop"),
                total_len=cache.total_len.at[t].set(tlen, mode="drop"),
                vals=cache.vals.at[t].set(seg, mode="drop"),
                version=cache.version.at[t].set(
                    jnp.int32(commit_version[i]), mode="drop"
                ),
                valid=cache.valid.at[t].set(True, mode="drop"),
                n_evict=cache.n_evict + jnp.where(active & evict, 1, 0),
            )
            return cache

        cache = jax.lax.fori_loop(0, C, write_chunk, cache)
        return cache._replace(
            n_insert=cache.n_insert + jnp.where(do, 1, 0),
            n_oversize=cache.n_oversize + jnp.where(mask[i] & oversize[i], 1, 0),
        )

    assert width >= L * C or width >= L, "leaves row narrower than one chunk"
    if width < L * C:  # pad so dynamic_slice stays in range
        pad = jnp.full((B, L * C - width), NULL_ID, leaves.dtype)
        leaves = jnp.concatenate([leaves, pad], axis=1)
    return jax.lax.fori_loop(0, B, body, cache)


def cache_delete(spec: CacheSpec, cache: CacheState, tpl_id, root, params, mask):
    """Exact-key write-around delete (all chunks). Batched scatter — deletes
    are idempotent so scatter races are harmless."""
    deleted = jnp.zeros(jnp.shape(root), bool)
    for c in range(spec.max_chunks):
        found, slot, _, _ = _probe(spec, cache, tpl_id, root, params, c)
        do = found & mask
        t = jnp.where(do, slot, spec.capacity)
        cache = cache._replace(valid=cache.valid.at[t].set(False, mode="drop"))
        deleted |= do
    return cache._replace(n_delete=cache.n_delete + jnp.sum(deleted.astype(jnp.int32)))


def sweep_root(spec: CacheSpec, cache: CacheState, tpl_id, root, mask):
    """``clearRange(template, root)`` — delete every cached instance of the
    template whose root is ``root``, regardless of parameter values
    (DeleteKeysForRoot / Algorithm 6)."""
    tpl_id = jnp.asarray(tpl_id, jnp.int32).reshape(-1)
    root = jnp.asarray(root, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    kill = (
        (cache.tpl[:, None] == tpl_id[None, :])
        & (cache.root[:, None] == root[None, :])
        & mask[None, :]
    ).any(axis=1)
    n = jnp.sum((kill & cache.valid).astype(jnp.int32))
    return cache._replace(valid=cache.valid & ~kill, n_delete=cache.n_delete + n)


def sweep_template(spec: CacheSpec, cache: CacheState, tpl_id):
    """``clearRange(template)`` — SC removal path (§4.1)."""
    kill = cache.tpl == jnp.asarray(tpl_id, jnp.int32)
    n = jnp.sum((kill & cache.valid).astype(jnp.int32))
    return cache._replace(valid=cache.valid & ~kill, n_delete=cache.n_delete + n)


def cache_stats(cache: CacheState) -> dict:
    occ = jnp.sum(cache.valid.astype(jnp.int32))
    return {
        "hits": int(cache.n_hit),
        "misses": int(cache.n_miss),
        "inserts": int(cache.n_insert),
        "evictions": int(cache.n_evict),
        "deletes": int(cache.n_delete),
        "oversize_skipped": int(cache.n_oversize),
        "occupancy": int(occ),
    }
