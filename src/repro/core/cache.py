"""The one-hop sub-query result cache (§4), as a tensor hash table.

Physical design (the FDB-subspace analogue):

- Open-addressing table of ``capacity`` slots (power of two), linear probe
  window of ``probes`` slots. Template id and root vertex id are stored
  *explicitly* per slot; the parameter vector is fingerprinted. This keeps
  FDB's two key-prefix operations cheap:
    * ``clearRange(template)``        -> ``sweep_template`` (vectorized mask)
    * ``clearRange(template, root)``  -> ``sweep_root``
- Values are padded leaf-id rows of ``max_leaves``; results larger than one
  slot spill into continuation *chunks* (the paper's 100KB FDB value-size
  chunking) — chunk i of a key lives at an independent hash. Results larger
  than ``max_chunks * max_leaves`` are not cached (counted), mirroring the
  paper's supernode discussion.
- Inserts hash all B x max_chunks chunk keys at once and commit them with a
  batched scatter (``cache_insert``). Intra-batch probe-window collisions
  are resolved by batch-order priority rounds inside a ``while_loop`` so the
  result is *byte-identical* to walking the batch sequentially — duplicate
  keys resolve last-writer-wins, and eviction keeps the documented
  last-probe-slot semantics. The common case (no overlapping windows)
  commits the whole batch in a single round. The original fori_loop walk is
  kept as ``cache_insert_sequential``, the reference the equivalence tests
  compare against.
- The read-path probe can run through the Pallas ``cache_probe`` kernel
  (``CacheSpec.use_pallas`` / the ``use_pallas`` argument of
  ``cache_lookup``); the jnp probe remains the fallback and reference.

Strong-consistency note: a fingerprint collision inside a probe window could
alias two different parameter vectors of the same (template, root). With 32b
slot-hash + 32b fingerprint + explicit (tpl, root) this is ~2^-64 per pair;
DESIGN.md §2 records the budget.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.keys import PARAM_LEN
from repro.utils import NULL_ID, hash_rows

_SEED_SLOT = 0x51ED5EED
_SEED_FP = 0xF1A9F00D

# cap on virtual rows (B * max_chunks) per vectorized-insert slab: bounds the
# O(N^2) collision masks at ~16MB while keeping one-round commits for every
# realistic CP batch
_INSERT_SLAB = 2048


class CacheSpec(NamedTuple):
    capacity: int = 4096  # power of two
    probes: int = 8
    max_leaves: int = 32  # leaf ids per slot (one FDB value chunk)
    max_chunks: int = 2  # continuation chunks per key
    use_pallas: bool = False  # route read-path probes through the TPU kernel


class CacheState(NamedTuple):
    tpl: jax.Array  # int32 [cap] (-1 = never used)
    root: jax.Array  # int32 [cap]
    fp: jax.Array  # uint32 [cap]
    chunk: jax.Array  # int32 [cap]
    total_len: jax.Array  # int32 [cap] (authoritative on chunk 0)
    vals: jax.Array  # int32 [cap, max_leaves]
    version: jax.Array  # int32 [cap] commit version of the populating txn
    valid: jax.Array  # bool [cap]
    # stats (0-d int32): read hits / read misses / inserts / evictions /
    # deletes / oversize results skipped
    n_hit: jax.Array
    n_miss: jax.Array
    n_insert: jax.Array
    n_evict: jax.Array
    n_delete: jax.Array
    n_oversize: jax.Array


def empty_cache(spec: CacheSpec) -> CacheState:
    cap = spec.capacity
    assert cap & (cap - 1) == 0, "capacity must be a power of two"
    z = jnp.int32(0)
    return CacheState(
        tpl=jnp.full((cap,), -1, jnp.int32),
        root=jnp.full((cap,), -1, jnp.int32),
        fp=jnp.zeros((cap,), jnp.uint32),
        chunk=jnp.zeros((cap,), jnp.int32),
        total_len=jnp.zeros((cap,), jnp.int32),
        vals=jnp.full((cap, spec.max_leaves), NULL_ID, jnp.int32),
        version=jnp.zeros((cap,), jnp.int32),
        valid=jnp.zeros((cap,), bool),
        n_hit=z, n_miss=z, n_insert=z, n_evict=z, n_delete=z, n_oversize=z,
    )


def _key_cols(tpl_id, root, params, chunk):
    tpl = jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), jnp.shape(root))
    ch = jnp.broadcast_to(jnp.asarray(chunk, jnp.int32), jnp.shape(root))
    cols = [tpl, jnp.asarray(root, jnp.int32)]
    cols += [params[..., i] for i in range(PARAM_LEN)]
    cols.append(ch)
    return cols


def _probe(spec: CacheSpec, cache: CacheState, tpl_id, root, params, chunk):
    """Find the slot holding (tpl, root, params, chunk). Returns (found, slot).

    ``chunk`` may be a scalar or an array broadcastable to ``root``'s shape
    (the vectorized insert probes every (row, chunk) key at once).
    """
    h = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_SLOT)
    fp = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_FP)
    ch = jnp.broadcast_to(jnp.asarray(chunk, jnp.int32), jnp.shape(root))
    base = (h & jnp.uint32(spec.capacity - 1)).astype(jnp.int32)
    offs = jnp.arange(spec.probes, dtype=jnp.int32)
    slots = (base[..., None] + offs) & (spec.capacity - 1)  # [..., P]
    match = (
        cache.valid[slots]
        & (cache.tpl[slots] == jnp.asarray(tpl_id, jnp.int32)[..., None])
        & (cache.root[slots] == jnp.asarray(root, jnp.int32)[..., None])
        & (cache.fp[slots] == fp[..., None])
        & (cache.chunk[slots] == ch[..., None])
    )
    found = jnp.any(match, axis=-1)
    first = jnp.argmax(match, axis=-1)
    slot = jnp.where(found, jnp.take_along_axis(slots, first[..., None], -1)[..., 0], -1)
    return found, slot, slots, fp


def _probe_pallas(spec: CacheSpec, cache: CacheState, tpl_id, root, params, chunk):
    """Pallas-kernel read probe: byte-identical to ``_probe``'s (found, slot).

    The kernel matches on (valid, tpl, root, fp); the chunk index is folded
    into the tpl channel (``tpl * max_chunks + chunk``) so the extra equality
    the jnp path performs on ``cache.chunk`` is preserved exactly. Never-used
    slots carry tpl = -1, whose folded value is negative and cannot collide
    with a real (tpl >= 0) query key.
    """
    from repro.kernels.cache_probe.ops import cache_probe

    h = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_SLOT)
    fp = hash_rows(_key_cols(tpl_id, root, params, chunk), _SEED_FP)
    C = spec.max_chunks
    tpl_b = jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), jnp.shape(root))
    ch = jnp.broadcast_to(jnp.asarray(chunk, jnp.int32), jnp.shape(root))
    c_tpl_eff = cache.tpl * C + cache.chunk
    found, slot = cache_probe(
        c_tpl_eff, cache.root, cache.fp, cache.valid,
        tpl_b * C + ch, jnp.asarray(root, jnp.int32), h, fp,
        probes=spec.probes,
    )
    return found, slot


def cache_lookup_lean(spec: CacheSpec, cache: CacheState, tpl_id, root, params,
                      use_pallas: bool | None = None):
    """Chain lookup returning ``(hit, leaves_raw, count, version)``.

    ``leaves_raw`` [B, max_chunks*max_leaves] holds the cached values
    left-packed: positions ``[0, count)`` are valid, the tail is whatever
    the slots carry — the caller must consume only the counted prefix.
    This is the fused hop pipeline's probe: validity is O(B) (a count per
    row) instead of the O(B*RW) mask+select the classic ``cache_lookup``
    materializes. A hit requires chunk 0 plus every continuation chunk
    implied by ``total_len`` (a partially-evicted chain is a miss). Stats
    are *not* updated here (pure read); the engine accumulates them.

    ``use_pallas`` routes the per-chunk probes through the Pallas
    ``cache_probe`` kernel (``None`` defers to ``spec.use_pallas``); the jnp
    probe is the fallback and reference — both return identical results.
    """
    L, C = spec.max_leaves, spec.max_chunks
    if use_pallas is None:
        use_pallas = spec.use_pallas

    def probe_chunk(c):
        if use_pallas:
            return _probe_pallas(spec, cache, tpl_id, root, params, c)
        f, s, _, _ = _probe(spec, cache, tpl_id, root, params, c)
        return f, s

    found0, slot0 = probe_chunk(0)
    tlen = jnp.where(found0, cache.total_len[jnp.clip(slot0, 0)], 0)
    need = jnp.clip((tlen + L - 1) // L, 1, C)  # chunks required
    B = jnp.shape(found0)
    leaves0 = cache.vals[jnp.clip(slot0, 0)]
    ok = found0
    if C > 1:
        # continuation chunks only matter for rows whose result spills past
        # chunk 0; when no row does (the common small-result case), skip
        # those probes and value gathers entirely.
        def probe_rest(_):
            fs, ls, tl = [], [], []
            for c in range(1, C):
                f, s = probe_chunk(c)
                fs.append(f)
                ls.append(cache.vals[jnp.clip(s, 0)])
                tl.append(cache.total_len[jnp.clip(s, 0)])
            return tuple(fs) + tuple(ls) + tuple(tl)

        def skip_rest(_):
            fs = (jnp.zeros(B, bool),) * (C - 1)
            ls = (jnp.full(B + (L,), NULL_ID, jnp.int32),) * (C - 1)
            tl = (jnp.zeros(B, jnp.int32),) * (C - 1)
            return fs + ls + tl

        rest = jax.lax.cond(jnp.any(need > 1), probe_rest, skip_rest, None)
        founds = (found0,) + rest[: C - 1]
        leaves_parts = (leaves0,) + rest[C - 1 : 2 * (C - 1)]
        tlens = rest[2 * (C - 1) :]
        for c in range(1, C):
            ok &= (need <= c) | founds[c]
            # chain consistency: continuation chunks carry the same total_len
            ok &= (need <= c) | (tlens[c - 1] == tlen)
        leaves_raw = jnp.concatenate(leaves_parts, axis=-1)
    else:
        leaves_raw = leaves0
    version = jnp.where(ok, cache.version[jnp.clip(slot0, 0)], -1)
    count = jnp.where(ok, tlen, 0)
    return ok, leaves_raw, count, version


def cache_lookup(spec: CacheSpec, cache: CacheState, tpl_id, root, params,
                 use_pallas: bool | None = None):
    """Batched read-path lookup (§3.1).

    Returns ``(hit [B], leaves [B, max_chunks*max_leaves], lmask, version)``
    with invalid positions masked to NULL_ID. See ``cache_lookup_lean`` for
    the count-based variant the fused engine uses.
    """
    ok, leaves_raw, count, version = cache_lookup_lean(
        spec, cache, tpl_id, root, params, use_pallas
    )
    pos = jnp.arange(spec.max_leaves * spec.max_chunks, dtype=jnp.int32)
    lmask = pos < count[..., None]
    leaves = jnp.where(lmask, leaves_raw, NULL_ID)
    return ok, leaves, lmask, version


def cache_insert(
    spec: CacheSpec,
    cache: CacheState,
    tpl_id,
    root,
    params,
    leaves,
    lens,
    commit_version,
    mask,
):
    """Vectorized write-path insert of B results (CP population /
    write-through) — byte-identical to ``cache_insert_sequential``.

    ``leaves``: int32 [B, >= max_chunks*max_leaves] compacted leaf ids.
    Oversize results are skipped and counted.

    All B x max_chunks chunk keys are hashed at once; each (row, chunk) is a
    *virtual row* whose priority is its sequential execution order. Rounds of
    a ``while_loop`` commit every virtual row none of whose earlier-priority
    window-overlapping peers is still pending, so each committed row sees
    exactly the cache state its sequential turn would have seen (matching
    slots reused last-writer-wins, first-empty placement, last-probe-slot
    eviction). Window overlap is the only cross-row hazard — slot validity
    only ever grows during an insert batch — so the common no-collision case
    commits everything in one round of pure batched scatters.

    Collision detection builds O(N^2) pairwise masks over the N = B*C
    virtual rows; batches are slabbed to at most ``_INSERT_SLAB`` virtual
    rows to bound that memory. Slabbing preserves the sequential contract
    exactly: inserting slab 2 into the state slab 1 produced *is* the
    sequential order.
    """
    L, C = spec.max_leaves, spec.max_chunks
    P, cap = spec.probes, spec.capacity
    B = leaves.shape[0]
    max_b = max(1, _INSERT_SLAB // C)
    if B > max_b:
        for lo in range(0, B, max_b):
            hi = min(lo + max_b, B)
            cache = cache_insert(
                spec, cache,
                jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), (B,))[lo:hi],
                jnp.asarray(root)[lo:hi], jnp.asarray(params)[lo:hi],
                leaves[lo:hi], jnp.asarray(lens)[lo:hi],
                jnp.asarray(commit_version)[lo:hi], jnp.asarray(mask)[lo:hi],
            )
        return cache
    width = leaves.shape[1]
    assert width >= L, "leaves row narrower than one chunk"
    if width < L * C:  # pad so the chunk reshape stays in range
        pad = jnp.full((B, L * C - width), NULL_ID, leaves.dtype)
        leaves = jnp.concatenate([leaves, pad], axis=1)
    elif width > L * C:
        leaves = leaves[:, : L * C]

    tpl_id = jnp.broadcast_to(jnp.asarray(tpl_id, jnp.int32), (B,))
    root = jnp.asarray(root, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    oversize = lens > L * C
    do = jnp.asarray(mask, bool) & ~oversize
    tlen = jnp.minimum(lens, L * C)
    nchunks = jnp.clip((tlen + L - 1) // L, 1, C)

    # ---- virtual rows: order o = b * C + c (sequential execution order) ----
    N = B * C
    rep = lambda x: jnp.repeat(x, C, axis=0)  # row-major expand over chunks
    tpl_v, root_v, tlen_v = rep(tpl_id), rep(root), rep(tlen)
    params_v = rep(jnp.asarray(params, jnp.int32))
    ver_v = rep(jnp.asarray(commit_version, jnp.int32))
    chunk_v = jnp.tile(jnp.arange(C, dtype=jnp.int32), B)
    active = rep(do) & (chunk_v < rep(nchunks))
    segs = leaves.astype(jnp.int32).reshape(N, L)
    seg_pos = chunk_v[:, None] * L + jnp.arange(L, dtype=jnp.int32)[None, :]
    segs = jnp.where(seg_pos < tlen_v[:, None], segs, NULL_ID)

    # ---- hash all N chunk keys at once ----
    h = hash_rows(_key_cols(tpl_v, root_v, params_v, chunk_v), _SEED_SLOT)
    fp_v = hash_rows(_key_cols(tpl_v, root_v, params_v, chunk_v), _SEED_FP)
    base = (h & jnp.uint32(cap - 1)).astype(jnp.int32)

    # probe windows [base, base + P) mod cap overlap iff the circular
    # distance between bases is < P in either direction
    d = jnp.mod(base[None, :] - base[:, None], cap)
    overlap = (d < P) | (d > cap - P)
    order = jnp.arange(N)
    earlier = order[None, :] < order[:, None]  # earlier[i, j]: j before i
    offs = jnp.arange(P, dtype=jnp.int32)

    def cond(state):
        _, committed, _ = state
        return jnp.any(active & ~committed)

    def body(state):
        cache, committed, n_evict = state
        pending = active & ~committed
        blocked = jnp.any(overlap & earlier & pending[None, :], axis=1)
        ready = pending & ~blocked
        slots = (base[:, None] + offs) & (cap - 1)  # [N, P]
        match = (
            cache.valid[slots]
            & (cache.tpl[slots] == tpl_v[:, None])
            & (cache.root[slots] == root_v[:, None])
            & (cache.fp[slots] == fp_v[:, None])
            & (cache.chunk[slots] == chunk_v[:, None])
        )
        found = jnp.any(match, axis=-1)
        mslot = jnp.take_along_axis(slots, jnp.argmax(match, -1)[:, None], -1)[:, 0]
        empty = ~cache.valid[slots]
        has_empty = jnp.any(empty, axis=-1)
        first_empty = jnp.take_along_axis(slots, jnp.argmax(empty, -1)[:, None], -1)[:, 0]
        # reuse matching slot, else first empty, else evict last probe slot
        target = jnp.where(found, mslot, jnp.where(has_empty, first_empty, slots[:, -1]))
        evict = ~found & ~has_empty & cache.valid[target]
        t = jnp.where(ready, target, cap)  # OOB -> drop
        cache = cache._replace(
            tpl=cache.tpl.at[t].set(tpl_v, mode="drop"),
            root=cache.root.at[t].set(root_v, mode="drop"),
            fp=cache.fp.at[t].set(fp_v, mode="drop"),
            chunk=cache.chunk.at[t].set(chunk_v, mode="drop"),
            total_len=cache.total_len.at[t].set(tlen_v, mode="drop"),
            vals=cache.vals.at[t].set(segs, mode="drop"),
            version=cache.version.at[t].set(ver_v, mode="drop"),
            valid=cache.valid.at[t].set(True, mode="drop"),
        )
        n_evict = n_evict + jnp.sum((ready & evict).astype(jnp.int32))
        return cache, committed | ready, n_evict

    cache, _, n_evict = jax.lax.while_loop(
        cond, body, (cache, jnp.zeros((N,), bool), jnp.int32(0))
    )
    return cache._replace(
        n_evict=cache.n_evict + n_evict,
        n_insert=cache.n_insert + jnp.sum(do.astype(jnp.int32)),
        n_oversize=cache.n_oversize
        + jnp.sum((jnp.asarray(mask, bool) & oversize).astype(jnp.int32)),
    )


def cache_insert_sequential(
    spec: CacheSpec,
    cache: CacheState,
    tpl_id,
    root,
    params,
    leaves,
    lens,
    commit_version,
    mask,
):
    """Reference insert: walks the batch with a fori_loop (the original write
    path). Kept as the oracle the vectorized ``cache_insert`` is tested
    against byte-for-byte; prefer ``cache_insert`` everywhere else.
    """
    L, C = spec.max_leaves, spec.max_chunks
    B = leaves.shape[0]
    width = leaves.shape[1]
    oversize = lens > L * C

    def body(i, cache):
        do = mask[i] & ~oversize[i]
        tlen = jnp.minimum(lens[i], L * C)
        nchunks = jnp.clip((tlen + L - 1) // L, 1, C)

        def write_chunk(c, cache):
            found, slot, slots, fp = _probe(
                spec, cache, tpl_id[i], root[i], params[i], c
            )
            empty = ~cache.valid[slots]
            has_empty = jnp.any(empty)
            first_empty = jnp.take_along_axis(
                slots, jnp.argmax(empty, -1)[None], -1
            )[0]
            # reuse matching slot, else first empty, else evict last probe
            target = jnp.where(found, slot, jnp.where(has_empty, first_empty, slots[-1]))
            evict = ~found & ~has_empty & cache.valid[target]
            active = do & (c < nchunks)
            t = jnp.where(active, target, spec.capacity)  # OOB -> drop
            seg = jax.lax.dynamic_slice(
                leaves[i], (c * L,), (L,)
            )
            seg = jnp.where(jnp.arange(L) < tlen - c * L, seg, NULL_ID)
            cache = cache._replace(
                tpl=cache.tpl.at[t].set(jnp.int32(tpl_id[i]), mode="drop"),
                root=cache.root.at[t].set(jnp.int32(root[i]), mode="drop"),
                fp=cache.fp.at[t].set(fp, mode="drop"),
                chunk=cache.chunk.at[t].set(c, mode="drop"),
                total_len=cache.total_len.at[t].set(tlen, mode="drop"),
                vals=cache.vals.at[t].set(seg, mode="drop"),
                version=cache.version.at[t].set(
                    jnp.int32(commit_version[i]), mode="drop"
                ),
                valid=cache.valid.at[t].set(True, mode="drop"),
                n_evict=cache.n_evict + jnp.where(active & evict, 1, 0),
            )
            return cache

        cache = jax.lax.fori_loop(0, C, write_chunk, cache)
        return cache._replace(
            n_insert=cache.n_insert + jnp.where(do, 1, 0),
            n_oversize=cache.n_oversize + jnp.where(mask[i] & oversize[i], 1, 0),
        )

    assert width >= L, "leaves row narrower than one chunk"
    if width < L * C:  # pad so dynamic_slice stays in range
        pad = jnp.full((B, L * C - width), NULL_ID, leaves.dtype)
        leaves = jnp.concatenate([leaves, pad], axis=1)
    return jax.lax.fori_loop(0, B, body, cache)


def cache_delete(spec: CacheSpec, cache: CacheState, tpl_id, root, params, mask):
    """Exact-key write-around delete (all chunks). Batched scatter — deletes
    are idempotent so scatter races are harmless."""
    deleted = jnp.zeros(jnp.shape(root), bool)
    for c in range(spec.max_chunks):
        found, slot, _, _ = _probe(spec, cache, tpl_id, root, params, c)
        do = found & mask
        t = jnp.where(do, slot, spec.capacity)
        cache = cache._replace(valid=cache.valid.at[t].set(False, mode="drop"))
        deleted |= do
    return cache._replace(n_delete=cache.n_delete + jnp.sum(deleted.astype(jnp.int32)))


def sweep_root(spec: CacheSpec, cache: CacheState, tpl_id, root, mask):
    """``clearRange(template, root)`` — delete every cached instance of the
    template whose root is ``root``, regardless of parameter values
    (DeleteKeysForRoot / Algorithm 6)."""
    tpl_id = jnp.asarray(tpl_id, jnp.int32).reshape(-1)
    root = jnp.asarray(root, jnp.int32).reshape(-1)
    mask = jnp.asarray(mask, bool).reshape(-1)
    kill = (
        (cache.tpl[:, None] == tpl_id[None, :])
        & (cache.root[:, None] == root[None, :])
        & mask[None, :]
    ).any(axis=1)
    n = jnp.sum((kill & cache.valid).astype(jnp.int32))
    return cache._replace(valid=cache.valid & ~kill, n_delete=cache.n_delete + n)


def sweep_template(spec: CacheSpec, cache: CacheState, tpl_id):
    """``clearRange(template)`` — SC removal path (§4.1)."""
    kill = cache.tpl == jnp.asarray(tpl_id, jnp.int32)
    n = jnp.sum((kill & cache.valid).astype(jnp.int32))
    return cache._replace(valid=cache.valid & ~kill, n_delete=cache.n_delete + n)


def cache_entries(spec: CacheSpec, cache: CacheState) -> list:
    """Canonical host-side dump of the logical cache contents.

    Returns a sorted list of per-slot tuples ``(tpl, root, fp, chunk,
    total_len, version, leaves)`` for every valid slot, with each chunk's
    leaf row trimmed to its occupied prefix. The dump is *layout-free*: the
    fingerprint is capacity-independent, so a single-host table and a
    sharded table (n blocks of capacity/n) holding the same logical entries
    dump identically — this is how the byte-identity tests compare gRW-Tx
    post-states across runtimes.
    """
    import numpy as np

    L = spec.max_leaves
    valid = np.asarray(cache.valid)
    tpl, root = np.asarray(cache.tpl), np.asarray(cache.root)
    fp, chunk = np.asarray(cache.fp), np.asarray(cache.chunk)
    tlen, ver = np.asarray(cache.total_len), np.asarray(cache.version)
    vals = np.asarray(cache.vals)
    out = []
    for s in np.nonzero(valid)[0]:
        seg = int(min(L, max(int(tlen[s]) - int(chunk[s]) * L, 0)))
        out.append((
            int(tpl[s]), int(root[s]), int(fp[s]), int(chunk[s]),
            int(tlen[s]), int(ver[s]), tuple(vals[s, :seg].tolist()),
        ))
    return sorted(out)


def cache_stats(cache: CacheState) -> dict:
    occ = jnp.sum(cache.valid.astype(jnp.int32))
    return {
        "hits": int(cache.n_hit),
        "misses": int(cache.n_miss),
        "inserts": int(cache.n_insert),
        "evictions": int(cache.n_evict),
        "deletes": int(cache.n_delete),
        "oversize_skipped": int(cache.n_oversize),
        "occupancy": int(occ),
    }
