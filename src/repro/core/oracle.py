"""Pure-python reference semantics for one-hop sub-queries.

The slow, obviously-correct oracle used by the hypothesis invariant tests
and as the conceptual ``ref`` for the Pallas onehop kernel: given the host
(numpy) view of a store, compute the exact leaf-id set of a template
instance. Mirrors Definition 2.1 directly.
"""

from __future__ import annotations

import numpy as np

from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    OP_EQ,
    OP_GE,
    OP_GT,
    OP_LE,
    OP_LT,
    OP_NEQ,
    PredSpec,
)
from repro.utils import PROP_MISSING

_MISSING = int(PROP_MISSING)
_OPS = {
    OP_EQ: lambda a, b: a == b,
    OP_NEQ: lambda a, b: a != b,
    OP_LT: lambda a, b: a < b,
    OP_LE: lambda a, b: a <= b,
    OP_GT: lambda a, b: a > b,
    OP_GE: lambda a, b: a >= b,
}


class HostStore:
    """Numpy snapshot of a GraphStore (device -> host once per check)."""

    def __init__(self, store):
        for f in (
            "vlabel", "valive", "vprops", "esrc", "edst", "elabel", "ealive",
            "eprops",
        ):
            setattr(self, f, np.asarray(getattr(store, f)))
        self.v_len = int(store.v_len)
        self.e_len = int(store.e_len)


def eval_pred_host(pred: PredSpec, label: int, props: np.ndarray, bound=None) -> bool:
    plabel = int(pred.label)
    if plabel >= 0 and label != plabel:
        return False
    for c in range(MAX_CONDS):
        pid = int(pred.prop_ids[c])
        if pid < 0:
            continue
        pv = int(props[pid])
        if pv == _MISSING:
            return False
        if bool(pred.wild[c]):
            if bound is None:
                continue  # presence is enough
            if pv != int(bound[c]):
                return False
        else:
            if not _OPS[int(pred.ops[c])](pv, int(pred.vals[c])):
                return False
    return True


def extract_wildcards_host(pred: PredSpec, props: np.ndarray):
    out = []
    for c in range(MAX_CONDS):
        pid = int(pred.prop_ids[c])
        if pid >= 0 and bool(pred.wild[c]):
            out.append(int(props[pid]))
        else:
            out.append(_MISSING)
    return out


def onehop_oracle(
    hs: HostStore,
    direction: int,
    edge_label: int,
    pr: PredSpec,
    pe: PredSpec,
    pl: PredSpec,
    root: int,
    params,
) -> set:
    """Exact leaf-id set of a one-hop sub-query instance at ``hs``."""
    params = np.asarray(params)
    pe_b, pl_b = params[:MAX_CONDS], params[MAX_CONDS:]
    if root < 0 or root >= len(hs.valive) or not hs.valive[root]:
        return set()
    if not eval_pred_host(pr, int(hs.vlabel[root]), hs.vprops[root]):
        return set()
    leaves = set()
    for e in range(hs.e_len):
        if not hs.ealive[e]:
            continue
        src, dst = int(hs.esrc[e]), int(hs.edst[e])
        cands = []
        if direction in (DIR_OUT, DIR_BOTH) and src == root:
            cands.append(dst)
        if direction in (DIR_IN, DIR_BOTH) and dst == root:
            cands.append(src)
        for leaf in cands:
            if leaf < 0 or leaf >= len(hs.valive) or not hs.valive[leaf]:
                continue
            if edge_label >= 0 and int(hs.elabel[e]) != edge_label:
                continue
            if not eval_pred_host(pe, int(hs.elabel[e]), hs.eprops[e], bound=pe_b):
                continue
            if not eval_pred_host(pl, int(hs.vlabel[leaf]), hs.vprops[leaf], bound=pl_b):
                continue
            leaves.add(leaf)
    return leaves
