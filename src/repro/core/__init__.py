"""The paper's contribution: a one-hop sub-query result cache.

Modules map 1:1 onto the paper:

- ``templates``   — Definitions 2.1/2.2: one-hop sub-query templates
                    ``(P^r, P^e, P^l)`` with wildcard predicates, tensorized.
- ``keys``        — §3: cache-key construction (template id, root vertex id,
                    wildcard values of P^e and P^l).
- ``cache``       — §4: the cache itself (open-addressing tensor hash table,
                    chunked values, sweep-deletes standing in for FDB
                    clearRange).
- ``engine``      — §3.1: gR-Tx processing — per-hop cache probe, miss
                    execution, miss enqueue, final clause.
- ``invalidation``— §3.2 + Appendix A: vectorized Algorithms 1–9
                    (write-around) and the write-through variant.
- ``population``  — §4: asynchronous transactional cache population (the CP
                    threads), with OCC conflict checks and bounded retries.
- ``lifecycle``   — §4.1: Service-Coordinator two-phase template
                    enable/disable state machine.
- ``rewrite``     — §4.2: query re-writing rules (Q+).
"""

from repro.core.templates import (
    ANY_LABEL,
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    OP_EQ,
    OP_GE,
    OP_GT,
    OP_LE,
    OP_LT,
    OP_NEQ,
    WILDCARD,
    PredSpec,
    Template,
    TemplateTable,
    evaluate_pred,
    extract_wildcards,
    make_pred,
    make_template_table,
)
from repro.core.keys import make_param_vec, key_fingerprint, key_slot_hash
from repro.core.cache import (
    CacheSpec,
    CacheState,
    cache_delete,
    cache_entries,
    cache_insert,
    cache_insert_sequential,
    cache_lookup,
    cache_stats,
    empty_cache,
    sweep_root,
    sweep_template,
)
from repro.core.runtime import (
    BUCKETS,
    LocalPlanTier,
    bucket_for,
    bucketize,
    decode_miss_records,
    get_grw_step,
    make_fused_plan_fn,
    make_hop_kernel,
    make_plan_fn,
    onehop_exec_view,
    pad_roots,
)
from repro.core.engine import (
    FINAL_COUNT,
    FINAL_IDS,
    FINAL_VALUES,
    EngineSpec,
    GraphEngine,
    Hop,
    MissRecord,
    QueryPlan,
    build_grw_step,
    onehop_exec,
    run_gr_tx_batch,
    run_grw_tx,
)
from repro.core.invalidation import invalidate_write_around, write_through_update
from repro.core.population import MissQueue, populate_step
from repro.core.lifecycle import ServiceCoordinator, TemplateState
from repro.core.rewrite import rewrite_plan

__all__ = [k for k in dir() if not k.startswith("_")]
