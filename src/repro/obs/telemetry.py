"""``ServeTelemetry`` — the serve-loop observability aggregator.

Composes the histogram, trace, and owner-stage pieces into the object
``repro.launch.serve`` drives: per-traffic-class streaming latency
histograms (cached vs. uncached gR-Txs, gRW commits, CP drains),
per-owner step-latency histograms, cumulative owner-stage counters, and
periodic JSONL snapshots plus an end-of-run report (both schema-valid
per :mod:`repro.obs.schema`).

Cached/uncached gR attribution is weighted at batch granularity: a
batch whose step took ``t`` seconds with ``h`` probe hits and ``m``
miss rows contributes ``t`` to the cached-class histogram with weight
``h`` and to the uncached class with weight ``m`` — the streaming
analogue of the paper's per-class response-time tables, without
tracking individual transactions through the fused device step.
"""

from __future__ import annotations

import time

import numpy as np

from repro.obs.histogram import LatencyHistogram
from repro.obs.metrics import (
    OWNER_STAGE_FIELDS,
    attribute_step_seconds,
    hit_locality,
    owner_stage_rows,
)
from repro.obs.schema import LATENCY_CLASSES, SCHEMA_VERSION
from repro.obs.trace import NULL_TRACER, JsonlTraceWriter, Tracer


class ServeTelemetry:
    """Aggregates serve-loop latency + owner-stage state; emits JSONL."""

    def __init__(self, n_shards: int, trace_path: str | None = None,
                 emit_spans: bool = True):
        self.n = int(n_shards)
        self.writer = JsonlTraceWriter(trace_path) if trace_path else None
        self.tracer = Tracer(sink=self.writer, emit_spans=emit_spans)
        self.latency = {cls: LatencyHistogram() for cls in LATENCY_CLASSES}
        self.owner_step = [LatencyHistogram() for _ in range(self.n)]
        self.owner_stage_total = np.zeros(
            (self.n, len(OWNER_STAGE_FIELDS)), dtype=np.int64)
        self.batches = 0
        self.counters: dict[str, int] = {}
        self._meta_emitted = False
        # meta must be the first event in the stream — emit it eagerly so
        # spans recorded before the first batch (e.g. the journal's
        # startup checkpoint) cannot precede it
        self._emit_meta()

    # -- recording --------------------------------------------------------

    def _emit_meta(self):
        if self.writer is None or self._meta_emitted:
            return
        self._meta_emitted = True
        self.writer.emit({
            "type": "meta", "version": SCHEMA_VERSION, "shards": self.n,
            "stage_fields": list(OWNER_STAGE_FIELDS), "ts": time.time(),
        })

    def record_gr(self, step_seconds: float, metrics: dict,
                  owner_stage=None) -> np.ndarray | None:
        """One gR batch. Returns the per-owner attributed seconds (or
        None when the runtime ran without device telemetry)."""
        self._emit_meta()
        self.batches += 1
        for k, v in metrics.items():
            if isinstance(v, (int, np.integer)):
                self.counters[k] = self.counters.get(k, 0) + int(v)
        hits = int(metrics.get("hits", 0))
        misses = int(metrics.get("misses", 0))
        self.latency["gr_cached"].record(step_seconds, weight=max(hits, 0))
        self.latency["gr_uncached"].record(step_seconds, weight=max(misses, 0))
        if owner_stage is None:
            return None
        stage = np.asarray(owner_stage, dtype=np.int64)
        self.owner_stage_total += stage
        per_owner = attribute_step_seconds(step_seconds, stage)
        for s in range(self.n):
            self.owner_step[s].record(float(per_owner[s]))
        return per_owner

    def record_grw(self, seconds: float) -> None:
        self._emit_meta()
        self.latency["grw"].record(seconds)

    def record_cp_drain(self, seconds: float) -> None:
        self._emit_meta()
        self.latency["cp_drain"].record(seconds)

    def bump(self, name: str, by: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + by

    # -- hit locality (cache-locality router signal) ----------------------

    def hit_locality(self) -> np.ndarray:
        return hit_locality(self.owner_stage_total)

    # -- snapshots / report -----------------------------------------------

    def _json_pct(self, h: LatencyHistogram) -> dict:
        pct = h.percentiles()
        out = {}
        for k, v in pct.items():
            if isinstance(v, float) and v != v:  # NaN -> null (empty class)
                out[k] = None
            else:
                out[k] = v
        return out

    def _state(self) -> dict:
        return {
            "owner_stage": owner_stage_rows(self.owner_stage_total),
            "hit_locality": [float(v) for v in self.hit_locality()],
            "latency": {cls: self._json_pct(h)
                        for cls, h in self.latency.items()},
            "owner_step_latency": [self._json_pct(h)
                                   for h in self.owner_step],
            "spans": self.tracer.snapshot(),
        }

    def snapshot(self, batch: int) -> dict:
        ev = {"type": "snapshot", "batch": int(batch), "ts": time.time(),
              **self._state()}
        self._emit_meta()
        if self.writer is not None:
            self.writer.emit(ev)
        return ev

    def report(self) -> dict:
        ev = {"type": "report", "batches": self.batches, "ts": time.time(),
              "counters": dict(self.counters), **self._state()}
        self._emit_meta()
        if self.writer is not None:
            self.writer.emit(ev)
            self.writer.flush()
        return ev

    def close(self) -> None:
        if self.writer is not None:
            self.writer.close()
