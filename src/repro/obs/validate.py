"""Validate a JSONL serve-loop trace against the event schema.

Usage::

    python -m repro.obs.validate trace.jsonl
    python -m repro.obs.validate trace.jsonl --expect-snapshots 3 \\
        --expect-report

Checks every line parses as JSON, every event validates against
:mod:`repro.obs.schema`, the first event is the ``meta`` header, the
owner-row shapes match the header's shard count, and (optionally) that
the trace contains at least N snapshots and a final report. Exit 0 on a
valid trace, 1 with the offending line number otherwise — this is the
CI gate behind the serve-loop tracing smoke.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.schema import validate_event


def validate_file(path: str, *, expect_snapshots: int = 0,
                  expect_report: bool = False) -> dict:
    """Validate; returns per-type event counts. Raises ValueError."""
    counts = {"meta": 0, "span": 0, "snapshot": 0, "report": 0}
    shards = None
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not valid JSON: {e}")
            try:
                t = validate_event(ev, shards=shards)
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}")
            if counts["meta"] == 0 and t != "meta":
                raise ValueError(
                    f"{path}:{lineno}: first event must be 'meta', got {t!r}")
            if t == "meta":
                if counts["meta"]:
                    raise ValueError(
                        f"{path}:{lineno}: duplicate 'meta' header")
                shards = ev["shards"]
            counts[t] += 1
    if counts["meta"] == 0:
        raise ValueError(f"{path}: empty trace (no 'meta' header)")
    if counts["snapshot"] < expect_snapshots:
        raise ValueError(
            f"{path}: expected >= {expect_snapshots} snapshots, got "
            f"{counts['snapshot']}")
    if expect_report and counts["report"] == 0:
        raise ValueError(f"{path}: no end-of-run report event")
    return counts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate a serve-loop JSONL trace")
    ap.add_argument("trace", help="path to the .jsonl trace file")
    ap.add_argument("--expect-snapshots", type=int, default=0,
                    help="fail unless the trace has at least N snapshots")
    ap.add_argument("--expect-report", action="store_true",
                    help="fail unless the trace ends with a report event")
    args = ap.parse_args(argv)
    try:
        counts = validate_file(args.trace,
                               expect_snapshots=args.expect_snapshots,
                               expect_report=args.expect_report)
    except (ValueError, OSError) as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    total = sum(counts.values())
    print(f"ok  {args.trace}: {total} events "
          f"({counts['span']} spans, {counts['snapshot']} snapshots, "
          f"{counts['report']} report)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
