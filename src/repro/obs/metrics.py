"""Per-owner/per-stage device metrics: field contract + host helpers.

The sharded serving step accumulates stage counters *per owner shard*
into a fixed-shape ``[n_shards, len(OWNER_STAGE_FIELDS)]`` int32 block
that rides the step's existing single stacked all-reduce (each shard
one-hot scatters its local stage counters at its own row; the psum of
the flattened block assembles the full matrix on every shard, adding
zero extra collectives). ``distributed.graph_serve._MeshTier`` owns the
device side; this module owns the field-order contract and the
host-side reads so neither drifts from the other.

Attribution sides (documented, deliberate):

- ``probe_hits`` / ``miss_rows`` / ``edges_scanned`` / ``leaf_fetches``
  and ``frontier_rows`` accumulate at the *owner* shard — the shard
  whose cache/storage segment actually did the work after routing.
- ``route_overflow`` and ``deferred_rows`` accumulate at the *origin*
  (querying) shard: overflow is detected before the exchange, and
  deferral is recorded against the home rows of the query.

``hit_locality`` is the per-shard cache hit-rate signal the future
cache-locality router (Smart Query Routing, PAPERS.md) will consume;
``attribute_step_seconds`` splits the measured collective-step
wall-clock across owners in proportion to attributed device work so the
``FailureDetector`` can mark a single straggler instead of the whole
mesh.
"""

from __future__ import annotations

import numpy as np

# Field order is the device contract: _MeshTier.reduce_metrics stacks
# its locals in exactly this order. Change both together (pinned by
# tests/test_sharded_collectives.py column-sum checks).
OWNER_STAGE_FIELDS = (
    "frontier_rows",   # owner-side frontier occupancy summed over hops
    "probe_hits",      # cache probe hits at the owner segment
    "miss_rows",       # miss rows executed against owner storage
    "edges_scanned",   # adjacency rows scanned by owner miss-exec
    "leaf_fetches",    # leaf fetches issued by owner miss-exec
    "route_overflow",  # origin-side rows dropped by route-cap overflow
    "deferred_rows",   # origin-side home rows deferred (degraded mode)
)

# Fields whose magnitude tracks device time spent; used to split the
# collective step wall-clock across owners.
WORK_FIELDS = ("frontier_rows", "edges_scanned")


def _as_matrix(owner_stage) -> np.ndarray:
    m = np.asarray(owner_stage, dtype=np.int64)
    if m.ndim != 2 or m.shape[1] != len(OWNER_STAGE_FIELDS):
        raise ValueError(
            f"owner_stage must be [n_shards, {len(OWNER_STAGE_FIELDS)}], "
            f"got shape {m.shape}")
    return m


def owner_stage_rows(owner_stage) -> list[dict]:
    """``[{field: int}]`` per owner — the JSONL snapshot shape."""
    m = _as_matrix(owner_stage)
    return [dict(zip(OWNER_STAGE_FIELDS, row.tolist())) for row in m]


def hit_locality(owner_stage) -> np.ndarray:
    """Per-owner cache hit rate: hits / (hits + miss_rows), NaN-free.

    Owners that saw no probes this step report 0.0 (no signal), so the
    vector is always finite and directly usable as router weights.
    """
    m = _as_matrix(owner_stage)
    hits = m[:, OWNER_STAGE_FIELDS.index("probe_hits")].astype(np.float64)
    miss = m[:, OWNER_STAGE_FIELDS.index("miss_rows")].astype(np.float64)
    denom = hits + miss
    out = np.zeros(m.shape[0], dtype=np.float64)
    nz = denom > 0
    out[nz] = hits[nz] / denom[nz]
    return out


def owner_load_share(owner_stage) -> np.ndarray:
    """Per-owner share of frontier-row load — the migration trigger.

    ``share[s] = frontier_rows[s] / sum(frontier_rows)``; a balanced mesh
    reads ``1/n`` everywhere, a hot owner reads above it. Zero total load
    returns the uniform ``1/n`` vector (no signal → no skew claimed).
    ``max(owner_load_share(...)) * n`` is the skew factor
    ``MigrationPolicy.load_share_trigger`` compares against, and its
    before/after ratio is BENCH_routing.json's hottest-owner-load-cut
    criterion.
    """
    m = _as_matrix(owner_stage)
    n = m.shape[0]
    rows = m[:, OWNER_STAGE_FIELDS.index("frontier_rows")].astype(np.float64)
    total = rows.sum()
    if total <= 0 or n == 0:
        return np.full(n, 1.0 / max(n, 1), dtype=np.float64)
    return rows / total


def attribute_step_seconds(step_seconds: float, owner_stage) -> np.ndarray:
    """Split one collective step's wall-clock across owners by work.

    ``per_owner[s] = step_seconds * work[s] / mean(work)`` where
    ``work = frontier_rows + edges_scanned``. On a balanced mesh every
    owner gets ``step_seconds`` — exactly the old collective-step
    semantics — while a hot owner is attributed proportionally more, so
    the ``FailureDetector`` can see *which* owner is dragging the step.
    A step with zero attributed work (all-hit, empty frontier) falls
    back to uniform attribution.
    """
    m = _as_matrix(owner_stage)
    n = m.shape[0]
    work = np.zeros(n, dtype=np.float64)
    for f in WORK_FIELDS:
        work += m[:, OWNER_STAGE_FIELDS.index(f)].astype(np.float64)
    total = work.sum()
    if total <= 0 or n == 0:
        return np.full(n, float(step_seconds), dtype=np.float64)
    return float(step_seconds) * work * n / total
