"""Runtime observability tier.

Three pieces, all dependency-free (numpy + stdlib only — safe to import
from device-side modules without pulling in jax):

- :mod:`repro.obs.histogram` — fixed-bucket log-scale latency histograms
  with exact merge algebra, giving streaming p50/p95/p99/p99.9 without
  storing raw samples.
- :mod:`repro.obs.trace` — a low-overhead ``Span``/``Tracer`` API for
  host-side per-phase wall-clock (dispatch, device step, unpack, journal
  flush, checkpoint, compaction tick, hot-swap pause), with optional
  structured JSONL export.
- :mod:`repro.obs.metrics` — the per-owner/per-stage device metrics
  block that rides the serving step's existing stacked all-reduce
  (field order contract + host-side attribution helpers, including the
  cache hit-locality signal for the future cache-locality router).

:mod:`repro.obs.telemetry` composes the three into ``ServeTelemetry``,
the serve-loop aggregator used by ``repro.launch.serve``;
:mod:`repro.obs.schema` validates the emitted JSONL trace events
(``python -m repro.obs.validate trace.jsonl``).

See ``docs/OBSERVABILITY.md`` for the trace format and how to read one.
"""

from repro.obs.histogram import LatencyHistogram
from repro.obs.metrics import (
    OWNER_STAGE_FIELDS,
    attribute_step_seconds,
    hit_locality,
    owner_stage_rows,
)
from repro.obs.trace import NULL_TRACER, JsonlTraceWriter, NullTracer, Tracer

__all__ = [
    "LatencyHistogram",
    "OWNER_STAGE_FIELDS",
    "attribute_step_seconds",
    "hit_locality",
    "owner_stage_rows",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "JsonlTraceWriter",
]
