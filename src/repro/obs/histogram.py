"""Fixed-bucket log-scale latency histograms with exact merge algebra.

The serve loop needs live p50/p95/p99/p99.9 for several traffic classes
(cached vs. uncached gR-Txs, gRW commits, CP drains) without storing raw
samples. A log-scale fixed-bucket histogram gives bounded relative error:
with ``buckets_per_decade = 16`` every bucket spans a ratio of
``10**(1/16) ~ 1.155``, so any quantile read off the histogram is within
~15% (one bucket) of the true sample quantile — far below the
run-to-run noise of wall-clock on shared hardware.

Merging is exact: two histograms with the same bucket spec merge by
adding counts, so ``merge(h1, h2)`` holds *exactly* the histogram that
would have been built from the concatenated sample streams. That makes
per-owner / per-batch histograms composable into run totals with no
approximation beyond the shared bucketing (property-tested in
``tests/test_obs.py``).

Quantiles use the weighted inverted-CDF rule (smallest bucket whose
cumulative count reaches ``q * total``) and report the bucket's
geometric midpoint, keeping the estimate within half a bucket of any
sample in that bucket.
"""

from __future__ import annotations

import math

import numpy as np

# Default range covers sub-microsecond device dispatch up to 100 s
# stalls; values outside clamp into the edge buckets.
DEFAULT_LO = 1e-7
DEFAULT_HI = 1e2
DEFAULT_BUCKETS_PER_DECADE = 16

REPORT_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99),
                    ("p999", 0.999))


class LatencyHistogram:
    """Streaming latency histogram over log-spaced buckets (seconds)."""

    __slots__ = ("lo", "hi", "buckets_per_decade", "n_buckets", "counts",
                 "sum_seconds", "_log_lo", "_inv_log_width")

    def __init__(self, lo: float = DEFAULT_LO, hi: float = DEFAULT_HI,
                 buckets_per_decade: int = DEFAULT_BUCKETS_PER_DECADE):
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self.lo = float(lo)
        self.hi = float(hi)
        self.buckets_per_decade = int(buckets_per_decade)
        decades = math.log10(self.hi / self.lo)
        self.n_buckets = max(1, int(math.ceil(decades * buckets_per_decade)))
        self.counts = np.zeros(self.n_buckets, dtype=np.int64)
        self.sum_seconds = 0.0
        self._log_lo = math.log10(self.lo)
        self._inv_log_width = float(self.buckets_per_decade)

    # -- bucket spec ------------------------------------------------------

    @property
    def spec(self) -> tuple[float, float, int]:
        return (self.lo, self.hi, self.buckets_per_decade)

    @property
    def resolution(self) -> float:
        """Width of one bucket as a ratio (adjacent bucket edges)."""
        return 10.0 ** (1.0 / self.buckets_per_decade)

    def _index(self, seconds: float) -> int:
        if seconds <= self.lo:
            return 0
        i = int((math.log10(seconds) - self._log_lo) * self._inv_log_width)
        return min(i, self.n_buckets - 1)

    # -- recording --------------------------------------------------------

    def record(self, seconds: float, weight: int = 1) -> None:
        if weight <= 0:
            return
        self.counts[self._index(float(seconds))] += weight
        self.sum_seconds += float(seconds) * weight

    def record_many(self, seconds, weights=None) -> None:
        a = np.asarray(seconds, dtype=np.float64).reshape(-1)
        if a.size == 0:
            return
        w = (np.ones(a.size, dtype=np.int64) if weights is None
             else np.asarray(weights, dtype=np.int64).reshape(-1))
        clipped = np.clip(a, self.lo, None)
        idx = ((np.log10(clipped) - self._log_lo) * self._inv_log_width)
        idx = np.clip(idx.astype(np.int64), 0, self.n_buckets - 1)
        np.add.at(self.counts, idx, w)
        self.sum_seconds += float(np.dot(a, w))

    # -- merge algebra ----------------------------------------------------

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Exact merge: counts add. Requires identical bucket specs."""
        if self.spec != other.spec:
            raise ValueError(
                f"cannot merge histograms with different bucket specs: "
                f"{self.spec} vs {other.spec}")
        out = LatencyHistogram(self.lo, self.hi, self.buckets_per_decade)
        out.counts = self.counts + other.counts
        out.sum_seconds = self.sum_seconds + other.sum_seconds
        return out

    def merge_in(self, other: "LatencyHistogram") -> "LatencyHistogram":
        if self.spec != other.spec:
            raise ValueError(
                f"cannot merge histograms with different bucket specs: "
                f"{self.spec} vs {other.spec}")
        self.counts += other.counts
        self.sum_seconds += other.sum_seconds
        return self

    # -- reading ----------------------------------------------------------

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    @property
    def mean(self) -> float:
        n = self.count
        return self.sum_seconds / n if n else float("nan")

    def _bucket_mid(self, i: int) -> float:
        # geometric midpoint of bucket i: lo * res^(i + 0.5)
        return self.lo * 10.0 ** ((i + 0.5) / self.buckets_per_decade)

    def quantile(self, q: float) -> float:
        """Inverted-CDF quantile (seconds); NaN when empty."""
        total = self.count
        if total == 0:
            return float("nan")
        if not (0.0 <= q <= 1.0):
            raise ValueError(f"quantile out of range: {q}")
        target = q * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, max(target, 1), side="left"))
        return self._bucket_mid(min(i, self.n_buckets - 1))

    def percentiles(self) -> dict:
        """The report shape: p50/p95/p99/p999 (+ count, mean)."""
        out = {name: self.quantile(q) for name, q in REPORT_QUANTILES}
        out["count"] = self.count
        out["mean"] = self.mean if self.count else None
        return out

    # -- serialization ----------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "lo": self.lo,
            "hi": self.hi,
            "buckets_per_decade": self.buckets_per_decade,
            "counts": self.counts.tolist(),
            "sum_seconds": self.sum_seconds,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyHistogram":
        h = cls(d["lo"], d["hi"], d["buckets_per_decade"])
        counts = np.asarray(d["counts"], dtype=np.int64)
        if counts.shape != h.counts.shape:
            raise ValueError("counts length does not match bucket spec")
        h.counts = counts
        h.sum_seconds = float(d["sum_seconds"])
        return h
