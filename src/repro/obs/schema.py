"""Hand-rolled validators for the JSONL trace event schema.

The container has no ``jsonschema`` package, so the schema is enforced
by plain predicate functions — one per event type — raising
``ValueError`` with a path-qualified message on the first violation.
``validate_event`` dispatches on ``event["type"]``:

- ``meta``     — one per trace, first line: run shape + field contract.
- ``span``     — one per traced phase execution: name + duration.
- ``snapshot`` — periodic serve-loop state: per-owner stage counters,
  hit locality, latency percentiles per traffic class, span aggregates.
- ``report``   — one per trace, last line: same shape as ``snapshot``
  plus run totals.

``docs/OBSERVABILITY.md`` documents every field;
``python -m repro.obs.validate trace.jsonl`` checks a file end to end.
"""

from __future__ import annotations

import math

from repro.obs.metrics import OWNER_STAGE_FIELDS

SCHEMA_VERSION = 1

EVENT_TYPES = ("meta", "span", "snapshot", "report")

# percentile keys every latency-class entry must carry
PCT_KEYS = ("p50", "p95", "p99", "p999")

# traffic classes the serve loop reports
LATENCY_CLASSES = ("gr_cached", "gr_uncached", "grw", "cp_drain")


def _fail(path: str, msg: str):
    raise ValueError(f"{path}: {msg}")


def _need(ev: dict, key: str, typ, path: str):
    if key not in ev:
        _fail(path, f"missing required key {key!r}")
    v = ev[key]
    if typ is float:
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            _fail(f"{path}.{key}", f"expected number, got {type(v).__name__}")
        return float(v)
    if typ is int:
        if isinstance(v, bool) or not isinstance(v, int):
            _fail(f"{path}.{key}", f"expected int, got {type(v).__name__}")
        return v
    if not isinstance(v, typ):
        _fail(f"{path}.{key}",
              f"expected {typ.__name__}, got {type(v).__name__}")
    return v


def _check_percentiles(d: dict, path: str):
    for k in PCT_KEYS:
        if k not in d:
            _fail(path, f"missing percentile {k!r}")
        v = d[k]
        if v is None:
            continue  # empty class: no samples yet
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            _fail(f"{path}.{k}", "expected number or null")
        if not math.isnan(v) and v < 0:
            _fail(f"{path}.{k}", f"negative latency {v}")
    n = _need(d, "count", int, path)
    if n < 0:
        _fail(f"{path}.count", "negative count")


def validate_meta(ev: dict):
    path = "meta"
    if _need(ev, "version", int, path) != SCHEMA_VERSION:
        _fail(f"{path}.version", f"expected {SCHEMA_VERSION}")
    n = _need(ev, "shards", int, path)
    if n < 1:
        _fail(f"{path}.shards", "must be >= 1")
    fields = _need(ev, "stage_fields", list, path)
    if tuple(fields) != OWNER_STAGE_FIELDS:
        _fail(f"{path}.stage_fields",
              f"field contract mismatch: {fields} != "
              f"{list(OWNER_STAGE_FIELDS)}")
    _need(ev, "ts", float, path)


def validate_span(ev: dict):
    path = "span"
    name = _need(ev, "name", str, path)
    if not name:
        _fail(f"{path}.name", "empty span name")
    d = _need(ev, "dur_s", float, path)
    if d < 0:
        _fail(f"{path}.dur_s", f"negative duration {d}")
    _need(ev, "ts", float, path)
    if "attrs" in ev and not isinstance(ev["attrs"], dict):
        _fail(f"{path}.attrs", "expected object")


def _check_state(ev: dict, path: str, *, shards: int | None):
    stage = _need(ev, "owner_stage", list, path)
    if shards is not None and len(stage) != shards:
        _fail(f"{path}.owner_stage",
              f"expected {shards} owner rows, got {len(stage)}")
    for i, row in enumerate(stage):
        if not isinstance(row, dict):
            _fail(f"{path}.owner_stage[{i}]", "expected object")
        for f in OWNER_STAGE_FIELDS:
            if f not in row:
                _fail(f"{path}.owner_stage[{i}]", f"missing field {f!r}")
            v = row[f]
            if isinstance(v, bool) or not isinstance(v, int) or v < 0:
                _fail(f"{path}.owner_stage[{i}].{f}",
                      f"expected non-negative int, got {v!r}")
    loc = _need(ev, "hit_locality", list, path)
    if len(loc) != len(stage):
        _fail(f"{path}.hit_locality", "length != n owner rows")
    for i, v in enumerate(loc):
        if isinstance(v, bool) or not isinstance(v, (int, float)) \
                or not (0.0 <= v <= 1.0):
            _fail(f"{path}.hit_locality[{i}]", f"expected rate in [0,1]: {v!r}")
    lat = _need(ev, "latency", dict, path)
    for cls in LATENCY_CLASSES:
        if cls not in lat:
            _fail(f"{path}.latency", f"missing class {cls!r}")
        _check_percentiles(lat[cls], f"{path}.latency.{cls}")
    owner_step = _need(ev, "owner_step_latency", list, path)
    if len(owner_step) != len(stage):
        _fail(f"{path}.owner_step_latency", "length != n owner rows")
    for i, d in enumerate(owner_step):
        if not isinstance(d, dict):
            _fail(f"{path}.owner_step_latency[{i}]", "expected object")
        _check_percentiles(d, f"{path}.owner_step_latency[{i}]")
    spans = _need(ev, "spans", dict, path)
    for name, agg in spans.items():
        if not isinstance(agg, dict):
            _fail(f"{path}.spans.{name}", "expected object")
        _need(agg, "count", int, f"{path}.spans.{name}")
        _need(agg, "total_s", float, f"{path}.spans.{name}")


def validate_snapshot(ev: dict, *, shards: int | None = None):
    path = "snapshot"
    b = _need(ev, "batch", int, path)
    if b < 0:
        _fail(f"{path}.batch", "negative batch index")
    _need(ev, "ts", float, path)
    _check_state(ev, path, shards=shards)


def validate_report(ev: dict, *, shards: int | None = None):
    path = "report"
    b = _need(ev, "batches", int, path)
    if b < 0:
        _fail(f"{path}.batches", "negative batch count")
    _need(ev, "ts", float, path)
    _need(ev, "counters", dict, path)
    _check_state(ev, path, shards=shards)


def validate_event(ev: dict, *, shards: int | None = None):
    """Validate one parsed JSONL event; raises ValueError on violation."""
    if not isinstance(ev, dict):
        raise ValueError(f"event must be an object, got {type(ev).__name__}")
    t = ev.get("type")
    if t not in EVENT_TYPES:
        raise ValueError(f"unknown event type {t!r} (expected one of "
                         f"{EVENT_TYPES})")
    if t == "meta":
        validate_meta(ev)
    elif t == "span":
        validate_span(ev)
    elif t == "snapshot":
        validate_snapshot(ev, shards=shards)
    else:
        validate_report(ev, shards=shards)
    return t
