"""Low-overhead ``Span``/``Tracer`` API with structured JSONL export.

The serve loop wraps each host-side phase (device dispatch, blocking
sync, result unpack, journal flush, checkpoint, compaction tick,
hot-swap pause) in ``tracer.span(name)``. A span costs one
``perf_counter`` pair plus a dict update (~1-2 us) — negligible against
multi-millisecond serve batches; ``tests/test_obs.py`` pins the bound.

``NullTracer`` (the module-level ``NULL_TRACER``) is the zero-cost
default: its ``span`` returns a shared re-entrant no-op context
manager, so instrumented code paths need no ``if tracing:`` branches.

When a sink (``JsonlTraceWriter``) is attached, every span additionally
emits one ``{"type": "span", ...}`` JSONL event; with or without a
sink, the tracer aggregates per-name call counts, total wall-clock, and
a :class:`~repro.obs.histogram.LatencyHistogram` for percentile
reporting. All entry points are thread-safe — the write-behind journal
flusher records spans from its background thread.
"""

from __future__ import annotations

import json
import threading
import time

from repro.obs.histogram import LatencyHistogram


class JsonlTraceWriter:
    """Append-only JSONL sink; one event object per line."""

    def __init__(self, path: str):
        self.path = str(path)
        self._f = open(self.path, "w", encoding="utf-8")
        self._lock = threading.Lock()
        self.events_written = 0

    def emit(self, event: dict) -> None:
        line = json.dumps(event, separators=(",", ":"), sort_keys=True)
        with self._lock:
            self._f.write(line + "\n")
            self.events_written += 1

    def flush(self) -> None:
        with self._lock:
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _Span:
    """Context manager timing one named phase; records into its tracer."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "seconds")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self.seconds = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.seconds = time.perf_counter() - self._t0
        self._tracer.record(self.name, self.seconds, self.attrs)
        return False


class _NullSpan:
    """Shared, re-entrant, stateless no-op span."""

    __slots__ = ()
    seconds = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every call is a no-op with near-zero cost."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def record(self, name: str, seconds: float, attrs: dict | None = None):
        pass

    def snapshot(self) -> dict:
        return {}


NULL_TRACER = NullTracer()


class Tracer:
    """Aggregates per-name span timings; optionally emits JSONL events.

    ``sink`` is a :class:`JsonlTraceWriter` (or anything with an
    ``emit(dict)`` method); when ``None`` the tracer only aggregates.
    """

    enabled = True

    def __init__(self, sink: JsonlTraceWriter | None = None,
                 emit_spans: bool = True):
        self.sink = sink
        self.emit_spans = emit_spans
        self._lock = threading.Lock()
        self._stats: dict[str, list] = {}          # name -> [count, total_s]
        self._hist: dict[str, LatencyHistogram] = {}

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs or None)

    def record(self, name: str, seconds: float,
               attrs: dict | None = None) -> None:
        with self._lock:
            st = self._stats.get(name)
            if st is None:
                self._stats[name] = [1, seconds]
                self._hist[name] = h = LatencyHistogram()
            else:
                st[0] += 1
                st[1] += seconds
                h = self._hist[name]
            h.record(seconds)
        if self.sink is not None and self.emit_spans:
            ev = {"type": "span", "name": name, "dur_s": seconds,
                  "ts": time.time()}
            if attrs:
                ev["attrs"] = attrs
            self.sink.emit(ev)

    def histogram(self, name: str) -> LatencyHistogram | None:
        with self._lock:
            return self._hist.get(name)

    def snapshot(self) -> dict:
        """Per-name aggregate view: count, total_s, p50/p95/p99/p999."""
        with self._lock:
            names = list(self._stats)
            out = {}
            for name in names:
                count, total = self._stats[name]
                pct = self._hist[name].percentiles()
                out[name] = {"count": int(count), "total_s": float(total),
                             **{k: pct[k] for k in ("p50", "p95", "p99",
                                                    "p999")}}
        return out
