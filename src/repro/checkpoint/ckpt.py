"""Atomic, compressed, reshardable checkpoints.

Layout: ``<dir>/step_<n>/`` with one ``<idx>.zst`` blob per pytree leaf
(zstd-compressed raw array bytes — §4's codec, reused on the persistence
path) plus ``manifest.json`` (treedef, shapes, dtypes, step). Writes go to
``step_<n>.tmp`` and are renamed into place, so a reader never observes a
torn checkpoint and a crashed writer leaves only a .tmp to garbage-collect.

Restore accepts target ``shardings`` — a checkpoint written on one mesh can
be restored onto a *different* mesh (elastic re-scale after node loss):
each leaf is loaded on host then ``jax.device_put`` with the new sharding.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None


def _comp(b: bytes) -> bytes:
    return zstd.ZstdCompressor(level=3).compress(b) if zstd else b


def _decomp(b: bytes) -> bytes:
    return zstd.ZstdDecompressor().decompress(b) if zstd else b


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
        with open(os.path.join(tmp, f"{i}.zst"), "wb") as f:
            f.write(_comp(arr.tobytes()))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template, shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(t_leaves) == len(manifest["leaves"]), "pytree mismatch"
    s_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(t_leaves)
    )
    out = []
    for i, (tmpl, meta, shard) in enumerate(zip(t_leaves, manifest["leaves"], s_leaves)):
        with open(os.path.join(path, f"{i}.zst"), "rb") as f:
            arr = np.frombuffer(_decomp(f.read()), dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        assert tuple(arr.shape) == tuple(tmpl.shape), f"leaf {i} shape mismatch"
        out.append(jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
